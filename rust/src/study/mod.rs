//! The declarative **Study** layer: compile multi-scenario sweeps into
//! deduplicated, shared-resource execution plans with streaming results.
//!
//! The paper's results are all *families* of scenarios — Fig. 2 sweeps
//! redundancy levels, the E[T]-vs-Var(T) trade-off sweeps ∆/µ grids, and
//! the diversity/parallelism and clone-scheduling literature both demand
//! dense grids over `(N, B, r, k, spec)`. A [`StudySpec`] describes such
//! a family declaratively — axes over cluster size × batch count ×
//! [`ReplicationPolicy`] × service spec × redundancy mode × k-of-B ×
//! worker speeds × backend, plus trial budgets and requested statistics
//! — and [`StudySpec::compile`] turns it into an [`ExecutionPlan`]:
//!
//! * **Canonicalized** — axis points are normalized before keying
//!   (`k = B` collapses to full completion on disjoint layouts,
//!   all-ones speed vectors to a homogeneous cluster, and batch counts that a policy ignores —
//!   `FullDiversity` is always one batch, `FullParallelism` always `N`
//!   — collapse to their canonical value), so equivalent requests are
//!   recognized as one cell.
//! * **Deduplicated** — identical `(scenario, backend, trials)` cells
//!   are planned **once** and fanned out to every axis point that
//!   requested them; `ExecutionPlan::deduped_points` counts the saved
//!   evaluations.
//! * **Shared-resource** — Monte-Carlo and DES cells are flattened into
//!   `(cell, shard)` work items over the fixed 64-logical-shard plan
//!   (`des::montecarlo::shard_plan`) and executed on **one** worker pool
//!   spanning the whole study, so cores stay saturated *across* cells
//!   instead of per-cell, while per-cell results stay bit-identical to
//!   the standalone `MonteCarloEvaluator`/`DesEvaluator` for any thread
//!   count. Analytic cells all run on the coordinating thread (grouped
//!   by cell key), so the whole study shares one thread-local `ct_cache`
//!   memo.
//! * **Streaming** — [`execute`](exec::execute) reports every cell through a
//!   progress callback as it completes and collects everything into a
//!   [`StudyReport`] with a versioned, schema-validated JSON artifact
//!   (plus CSV emit for plotting).
//!
//! Scenario seeds are derived deterministically from
//! `(StudySpec::seed, canonical cell key)`, so a study is reproducible
//! from its spec alone and the report is bit-deterministic per seed for
//! **any** thread count (live cells excepted — they measure wall clock).

pub mod exec;
pub mod report;

pub use exec::execute;
pub use report::{
    validate_file, validate_json, CellOutcome, CellResult, StudyReport, SCHEMA_VERSION,
};

use crate::des::engine::Redundancy;
use crate::des::Scenario;
use crate::dist::{BatchModel, BatchService, ServiceSpec};
use crate::evaluator::ReplicationPolicy;
use crate::util::json::Json;
use std::collections::BTreeMap;

// ---------------------------------------------------------------------
// Axes
// ---------------------------------------------------------------------

/// Which evaluation backend a cell runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendSel {
    /// Closed forms (exact or provably bounded; trial budget 0).
    Analytic,
    /// Block-sampled Monte-Carlo trials (`StudySpec::mc_trials`).
    MonteCarlo,
    /// Discrete-event engine trials (`StudySpec::des_trials`).
    Des,
    /// The live coordinator with injected time (`StudySpec::live_rounds`
    /// rounds; wall-clock, not bit-deterministic).
    Live,
}

impl BackendSel {
    /// Every backend, in canonical order.
    pub fn all() -> &'static [BackendSel] {
        &[BackendSel::Analytic, BackendSel::MonteCarlo, BackendSel::Des, BackendSel::Live]
    }

    /// Stable identifier (spec files, artifacts, tables).
    pub fn name(self) -> &'static str {
        match self {
            BackendSel::Analytic => "analytic",
            BackendSel::MonteCarlo => "montecarlo",
            BackendSel::Des => "des",
            BackendSel::Live => "live",
        }
    }

    /// Parse a backend name.
    pub fn parse(s: &str) -> anyhow::Result<BackendSel> {
        BackendSel::all()
            .iter()
            .copied()
            .find(|b| b.name() == s)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown backend '{s}' (accepted: analytic, montecarlo, des, live)"
                )
            })
    }
}

/// Batch-count axis.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BatchAxis {
    /// Every feasible batch count of each cluster size (the divisors of
    /// `N` — the paper's spectrum).
    Feasible,
    /// An explicit list of batch counts.
    Explicit(Vec<usize>),
}

/// Redundancy-mode axis entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RedundancyAxis {
    /// All replicas start at t = 0 (the paper's model).
    Upfront,
    /// Speculative relaunch with the given deadline factor.
    Speculative(f64),
}

impl RedundancyAxis {
    /// The engine-level redundancy mode.
    pub fn to_redundancy(self) -> Redundancy {
        match self {
            RedundancyAxis::Upfront => Redundancy::Upfront,
            RedundancyAxis::Speculative(f) => Redundancy::Speculative { deadline_factor: f },
        }
    }

    /// Stable label (spec files, cell keys, CSV).
    pub fn label(self) -> String {
        match self {
            RedundancyAxis::Upfront => "upfront".to_string(),
            RedundancyAxis::Speculative(f) => format!("speculative:{f}"),
        }
    }

    /// Parse `upfront` or `speculative:FACTOR`.
    pub fn parse(s: &str) -> anyhow::Result<RedundancyAxis> {
        if s == "upfront" {
            return Ok(RedundancyAxis::Upfront);
        }
        if let Some(rest) = s.strip_prefix("speculative:") {
            let f: f64 = rest.trim().parse().map_err(|e| {
                anyhow::anyhow!("bad speculative deadline factor '{rest}': {e}")
            })?;
            anyhow::ensure!(f > 0.0, "speculative deadline factor must be positive, got {f}");
            return Ok(RedundancyAxis::Speculative(f));
        }
        anyhow::bail!(
            "unknown redundancy mode '{s}' (accepted: upfront, speculative:FACTOR)"
        )
    }
}

/// k-of-B partial-aggregation axis entry. On disjoint layouts,
/// resolution canonicalizes `k = B` to full completion, so `Full`,
/// `Fraction(1.0)`, and `Exact(B)` all plan the same cell. Overlapping
/// layouts keep `k = B`: their native full completion is the *coverage*
/// rule (finished windows covering every unit, possibly before every
/// window finishes), a strictly different — earlier — event than
/// waiting for the B-th window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KTarget {
    /// Wait for every batch.
    Full,
    /// Wait for the earliest `round(f · B)` batches (clamped to `[1, B]`).
    Fraction(f64),
    /// Wait for the earliest `k` batches exactly (`1 ≤ k ≤ B` required).
    Exact(usize),
}

impl KTarget {
    /// Resolve against a scenario's effective batch count; `None` means
    /// the scenario's native full completion. `collapse_full` controls
    /// whether `k = B` canonicalizes to `None` — true for disjoint
    /// layouts (where the two are the same event), false for
    /// overlapping layouts (where full completion is the earlier
    /// coverage rule).
    pub fn resolve(self, eff_b: usize, collapse_full: bool) -> anyhow::Result<Option<usize>> {
        let k = match self {
            KTarget::Full => return Ok(None),
            KTarget::Fraction(f) => {
                anyhow::ensure!(
                    f > 0.0 && f <= 1.0,
                    "k-of-B fraction must be in (0, 1], got {f}"
                );
                ((f * eff_b as f64).round() as usize).clamp(1, eff_b)
            }
            KTarget::Exact(k) => {
                anyhow::ensure!(
                    k >= 1 && k <= eff_b,
                    "k-of-B target must satisfy 1 <= k <= B (got k={k}, B={eff_b})"
                );
                k
            }
        };
        Ok(if k == eff_b && collapse_full { None } else { Some(k) })
    }

    /// Stable label (spec files, CSV).
    pub fn label(self) -> String {
        match self {
            KTarget::Full => "full".to_string(),
            KTarget::Fraction(f) => format!("frac:{f}"),
            KTarget::Exact(k) => format!("k:{k}"),
        }
    }

    /// Parse a label: `full`, `k:N`, or `frac:F`.
    pub fn parse(s: &str) -> anyhow::Result<KTarget> {
        if s == "full" {
            return Ok(KTarget::Full);
        }
        if let Some(rest) = s.strip_prefix("k:") {
            let k: usize = rest
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("bad k-of-B target '{s}': {e}"))?;
            anyhow::ensure!(k >= 1, "k-of-B target must be >= 1, got {k}");
            return Ok(KTarget::Exact(k));
        }
        if let Some(rest) = s.strip_prefix("frac:") {
            let f: f64 = rest
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("bad k-of-B fraction '{s}': {e}"))?;
            anyhow::ensure!(
                f > 0.0 && f <= 1.0,
                "k-of-B fraction must be in (0, 1], got {f}"
            );
            return Ok(KTarget::Fraction(f));
        }
        anyhow::bail!("unknown k-of-B target '{s}' (accepted: full, k:N, frac:F)")
    }
}

/// Worker-speed axis entry. Resolution canonicalizes an all-ones speed
/// vector to the homogeneous cluster.
#[derive(Debug, Clone, PartialEq)]
pub enum SpeedAxis {
    /// Every worker at unit speed.
    Homogeneous,
    /// Linear ramp of speed factors from `lo` (worker 0) to `hi`
    /// (worker N−1).
    Ramp {
        /// Factor of the fastest-dispatch end.
        lo: f64,
        /// Factor of the other end.
        hi: f64,
    },
    /// Explicit per-worker factors (length must equal the cluster size).
    Explicit(Vec<f64>),
}

impl SpeedAxis {
    /// Resolve to per-worker factors for an `n`-worker cluster; `None`
    /// means homogeneous (including any vector of all exact 1.0s).
    pub fn resolve(&self, n: usize) -> anyhow::Result<Option<Vec<f64>>> {
        let v: Vec<f64> = match self {
            SpeedAxis::Homogeneous => return Ok(None),
            SpeedAxis::Ramp { lo, hi } => {
                anyhow::ensure!(
                    *lo > 0.0 && *hi > 0.0,
                    "speed ramp endpoints must be positive, got lo={lo}, hi={hi}"
                );
                (0..n)
                    .map(|w| {
                        if n == 1 {
                            *lo
                        } else {
                            lo + (hi - lo) * w as f64 / (n - 1) as f64
                        }
                    })
                    .collect()
            }
            SpeedAxis::Explicit(v) => {
                anyhow::ensure!(
                    v.len() == n,
                    "explicit speed vector has {} factors but the cluster has {n} workers",
                    v.len()
                );
                anyhow::ensure!(
                    v.iter().all(|&c| c > 0.0),
                    "speed factors must be positive, got {v:?}"
                );
                v.clone()
            }
        };
        Ok(if v.iter().all(|&c| c == 1.0) { None } else { Some(v) })
    }

    /// Stable label (spec files, CSV).
    pub fn label(&self) -> String {
        match self {
            SpeedAxis::Homogeneous => "homogeneous".to_string(),
            SpeedAxis::Ramp { lo, hi } => format!("ramp:{lo},{hi}"),
            SpeedAxis::Explicit(v) => format!("explicit:{v:?}"),
        }
    }

    /// Parse `homogeneous` or `ramp:LO,HI` (explicit vectors only exist
    /// as JSON arrays).
    pub fn parse(s: &str) -> anyhow::Result<SpeedAxis> {
        if s == "homogeneous" {
            return Ok(SpeedAxis::Homogeneous);
        }
        if let Some(rest) = s.strip_prefix("ramp:") {
            let (lo, hi) = rest.split_once(',').ok_or_else(|| {
                anyhow::anyhow!("speed ramp '{s}' needs two comma-separated factors")
            })?;
            let lo: f64 = lo.trim().parse().map_err(|e| {
                anyhow::anyhow!("bad speed ramp endpoint '{lo}': {e}")
            })?;
            let hi: f64 = hi.trim().parse().map_err(|e| {
                anyhow::anyhow!("bad speed ramp endpoint '{hi}': {e}")
            })?;
            return Ok(SpeedAxis::Ramp { lo, hi });
        }
        anyhow::bail!(
            "unknown speed axis '{s}' (accepted: homogeneous, ramp:LO,HI, or a JSON \
             array of per-worker factors)"
        )
    }
}

/// Live-backend knobs (only consulted when the `live` backend is on an
/// axis).
#[derive(Debug, Clone, PartialEq)]
pub struct LiveKnobs {
    /// Wall-clock seconds per unit of injected service time.
    pub time_scale: f64,
    /// Dataset rows.
    pub n_samples: usize,
    /// Model feature dimension.
    pub dim: usize,
    /// Use the PJRT compute backend instead of the pure-Rust mock.
    pub pjrt: bool,
    /// Artifact directory for the PJRT backend.
    pub artifacts_dir: Option<String>,
    /// Cancel sibling replicas when a batch completes.
    pub cancellation: bool,
}

impl Default for LiveKnobs {
    fn default() -> Self {
        Self {
            time_scale: 0.002,
            n_samples: 64,
            dim: 4,
            pjrt: false,
            artifacts_dir: None,
            cancellation: true,
        }
    }
}

// ---------------------------------------------------------------------
// The declarative spec
// ---------------------------------------------------------------------

/// Accepted top-level fields of a study spec file (the error message of
/// any unknown field lists these).
pub const SPEC_FIELDS: &[&str] = &[
    "name",
    "n_workers",
    "batches",
    "policies",
    "services",
    "batch_model",
    "redundancy",
    "k_of_b",
    "speeds",
    "verify_m",
    "backends",
    "mc_trials",
    "des_trials",
    "live_rounds",
    "des_cancellation",
    "live",
    "seed",
    "quantiles",
    "cost",
];

/// Accepted keys of the nested `live` spec object.
pub const LIVE_FIELDS: &[&str] =
    &["time_scale", "n_samples", "dim", "pjrt", "cancellation", "artifacts_dir"];

/// A declarative multi-scenario study: the cartesian product of its
/// axes, evaluated by every backend on the `backends` axis. See the
/// module docs for the compile/dedup/execution pipeline.
#[derive(Debug, Clone)]
pub struct StudySpec {
    /// Study name (artifact `study` field, default artifact file stem).
    pub name: String,
    /// Cluster sizes `N`.
    pub n_workers: Vec<usize>,
    /// Batch counts per cluster size.
    pub batches: BatchAxis,
    /// Replication policies.
    pub policies: Vec<ReplicationPolicy>,
    /// Batch service laws (service spec + batch model).
    pub services: Vec<BatchService>,
    /// Redundancy activation modes.
    pub redundancy: Vec<RedundancyAxis>,
    /// k-of-B partial-aggregation targets.
    pub k_targets: Vec<KTarget>,
    /// Worker-speed profiles.
    pub speeds: Vec<SpeedAxis>,
    /// m-of-g result verification: `0` or `1` leaves verification off;
    /// `m >= 2` makes every cell wait for the m-th replica of each
    /// batch and vote on result agreement (the scenarios carry
    /// [`Scenario::verify_m`]). Requires upfront redundancy and a
    /// replication degree of at least `m` at every axis point.
    pub verify_m: usize,
    /// Evaluation backends (each axis point is evaluated by every one).
    pub backends: Vec<BackendSel>,
    /// Monte-Carlo trials per cell.
    pub mc_trials: u64,
    /// DES trials per cell.
    pub des_trials: u64,
    /// Live rounds per cell.
    pub live_rounds: u64,
    /// DES replica cancellation (the engine knob that is not a scenario
    /// field).
    pub des_cancellation: bool,
    /// Live-backend knobs.
    pub live: LiveKnobs,
    /// Root seed: every cell's scenario seed is derived from this and
    /// the cell's canonical key.
    pub seed: u64,
    /// Emit per-cell quantiles into the artifact/CSV.
    pub quantiles: bool,
    /// Emit per-cell redundancy cost into the artifact/CSV.
    pub cost: bool,
}

impl StudySpec {
    /// A spec skeleton with every non-axis knob at its default; callers
    /// fill the axes via struct-update syntax.
    pub fn base(name: &str) -> Self {
        Self {
            name: name.to_string(),
            n_workers: Vec::new(),
            batches: BatchAxis::Feasible,
            policies: vec![ReplicationPolicy::BalancedDisjoint],
            services: Vec::new(),
            redundancy: vec![RedundancyAxis::Upfront],
            k_targets: vec![KTarget::Full],
            speeds: vec![SpeedAxis::Homogeneous],
            verify_m: 0,
            backends: vec![BackendSel::MonteCarlo],
            mc_trials: 100_000,
            des_trials: 20_000,
            live_rounds: 30,
            des_cancellation: true,
            live: LiveKnobs::default(),
            seed: 42,
            quantiles: true,
            cost: true,
        }
    }

    /// Smoke-quality budgets for CI and quick iterations.
    pub fn fast(mut self) -> Self {
        self.mc_trials = (self.mc_trials / 5).max(2_000);
        self.des_trials = (self.des_trials / 5).max(500);
        self.live_rounds = (self.live_rounds / 3).max(10);
        self
    }

    /// Trial budget of one backend.
    pub fn trials_for(&self, backend: BackendSel) -> u64 {
        match backend {
            BackendSel::Analytic => 0,
            BackendSel::MonteCarlo => self.mc_trials,
            BackendSel::Des => self.des_trials,
            BackendSel::Live => self.live_rounds,
        }
    }

    /// Compile the spec into a deduplicated [`ExecutionPlan`]: enumerate
    /// the cartesian product in canonical axis order (services ×
    /// clusters × batches × policies × redundancy × k × speeds ×
    /// backends), canonicalize each point, derive its scenario seed from
    /// the canonical key, and unify identical `(scenario, backend,
    /// trials)` cells.
    pub fn compile(&self) -> anyhow::Result<ExecutionPlan> {
        let axis = |name: &str, empty: bool| -> anyhow::Result<()> {
            anyhow::ensure!(
                !empty,
                "StudySpec::{name} axis is empty (need at least one entry)"
            );
            Ok(())
        };
        axis("n_workers", self.n_workers.is_empty())?;
        axis("services", self.services.is_empty())?;
        axis("policies", self.policies.is_empty())?;
        axis("redundancy", self.redundancy.is_empty())?;
        axis("k_targets", self.k_targets.is_empty())?;
        axis("speeds", self.speeds.is_empty())?;
        axis("backends", self.backends.is_empty())?;
        if let BatchAxis::Explicit(v) = &self.batches {
            axis("batches", v.is_empty())?;
        }
        if self.verify_m >= 2 {
            anyhow::ensure!(
                self.redundancy.iter().all(|r| matches!(r, RedundancyAxis::Upfront)),
                "StudySpec::verify_m = {} requires upfront redundancy on every \
                 'redundancy' axis entry; m-of-g voting is undefined for \
                 speculative relaunch",
                self.verify_m
            );
        }
        for &backend in &self.backends {
            anyhow::ensure!(
                backend == BackendSel::Analytic || self.trials_for(backend) >= 1,
                "StudySpec trial budget for backend '{}' is 0 (set {})",
                backend.name(),
                match backend {
                    BackendSel::MonteCarlo => "mc_trials",
                    BackendSel::Des => "des_trials",
                    _ => "live_rounds",
                }
            );
        }

        let mut scenarios: Vec<Scenario> = Vec::new();
        let mut scen_idx: BTreeMap<String, usize> = BTreeMap::new();
        let mut cells: Vec<PlannedCell> = Vec::new();
        let mut cell_idx: BTreeMap<String, usize> = BTreeMap::new();
        let mut points: Vec<PlannedPoint> = Vec::new();

        for (si, svc) in self.services.iter().enumerate() {
            let skey = service_key(svc);
            for &n in &self.n_workers {
                anyhow::ensure!(
                    n >= 1,
                    "StudySpec::n_workers contains {n}; cluster sizes must be >= 1"
                );
                let blist: Vec<usize> = match &self.batches {
                    BatchAxis::Feasible => crate::assignment::feasible_batch_counts(n),
                    BatchAxis::Explicit(v) => v.clone(),
                };
                for &b in &blist {
                    for &policy in &self.policies {
                        let eff_b = effective_batches(policy, n, b);
                        // Canonical batch identity: FullDiversity and
                        // FullParallelism ignore the requested b, so
                        // every b plans the same physical cell.
                        // (OverlappingCyclic keeps b — its window size
                        // is N/b.)
                        let key_b = match policy {
                            ReplicationPolicy::FullDiversity => 1,
                            ReplicationPolicy::FullParallelism => n,
                            _ => b,
                        };
                        for (ri, red) in self.redundancy.iter().enumerate() {
                            for (ki, kt) in self.k_targets.iter().enumerate() {
                                let collapse_full =
                                    policy != ReplicationPolicy::OverlappingCyclic;
                                let k = kt.resolve(eff_b, collapse_full).map_err(|e| {
                                    anyhow::anyhow!(
                                        "StudySpec::k_targets[{ki}] = {} at axis point \
                                         (N={n}, B={b}, policy={}): {e}",
                                        kt.label(),
                                        policy.name()
                                    )
                                })?;
                                for (wi, sp) in self.speeds.iter().enumerate() {
                                    let speeds = sp.resolve(n).map_err(|e| {
                                        anyhow::anyhow!(
                                            "StudySpec::speeds[{wi}] = {} at axis point \
                                             (N={n}): {e}",
                                            sp.label()
                                        )
                                    })?;
                                    let speeds_key = match &speeds {
                                        None => "homogeneous".to_string(),
                                        Some(v) => format!("{v:?}"),
                                    };
                                    let mut structural = format!(
                                        "n={n};b={key_b};policy={};service={skey};red={};\
                                         k={k:?};speeds={speeds_key}",
                                        policy.name(),
                                        red.label()
                                    );
                                    // The verify knob changes the completion
                                    // law, so it joins the canonical key —
                                    // but only when on, keeping legacy keys
                                    // (and their derived seeds) stable.
                                    if self.verify_m >= 2 {
                                        structural =
                                            format!("{structural};verify={}", self.verify_m);
                                    }
                                    let scn_i = match scen_idx.get(&structural) {
                                        Some(&i) => i,
                                        None => {
                                            let seed = derive_seed(self.seed, &structural);
                                            let mut scn = Scenario::from_policy(
                                                policy,
                                                n,
                                                key_b,
                                                svc.clone(),
                                                seed,
                                            )
                                            .map_err(|e| {
                                                anyhow::anyhow!(
                                                    "StudySpec axis point (N={n}, B={b}, \
                                                     policy={}): {e}",
                                                    policy.name()
                                                )
                                            })?
                                            .with_redundancy(red.to_redundancy());
                                            if let Some(kv) = k {
                                                scn = scn.with_k_of_b(kv)?;
                                            }
                                            if let Some(v) = speeds.clone() {
                                                scn = scn.with_speeds(v)?;
                                            }
                                            if self.verify_m >= 2 {
                                                scn = scn
                                                    .with_verify_m(self.verify_m)
                                                    .map_err(|e| {
                                                        anyhow::anyhow!(
                                                            "StudySpec::verify_m = {} at axis \
                                                             point (N={n}, B={b}, policy={}): {e}",
                                                            self.verify_m,
                                                            policy.name()
                                                        )
                                                    })?;
                                            }
                                            scenarios.push(scn);
                                            scen_idx
                                                .insert(structural.clone(), scenarios.len() - 1);
                                            scenarios.len() - 1
                                        }
                                    };
                                    for &backend in &self.backends {
                                        let trials = self.trials_for(backend);
                                        let ck = format!(
                                            "{structural}|backend={};trials={trials}",
                                            backend.name()
                                        );
                                        let cell = match cell_idx.get(&ck) {
                                            Some(&i) => i,
                                            None => {
                                                cells.push(PlannedCell {
                                                    scenario: scenarios[scn_i].clone(),
                                                    backend,
                                                    trials,
                                                    key: ck.clone(),
                                                });
                                                cell_idx.insert(ck, cells.len() - 1);
                                                cells.len() - 1
                                            }
                                        };
                                        points.push(PlannedPoint {
                                            coords: PointCoords {
                                                n,
                                                b,
                                                eff_b,
                                                policy,
                                                service_idx: si,
                                                service: skey.clone(),
                                                redundancy_idx: ri,
                                                redundancy: red.label(),
                                                k_idx: ki,
                                                k_of_b: k,
                                                speeds_idx: wi,
                                                speeds: speeds_key.clone(),
                                                backend,
                                            },
                                            cell,
                                        });
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(ExecutionPlan { spec: self.clone(), scenarios, cells, points })
    }

    // -----------------------------------------------------------------
    // Presets and spec files
    // -----------------------------------------------------------------

    /// Names of the built-in presets.
    pub fn preset_names() -> &'static [&'static str] {
        &["smoke", "fig2", "tradeoff", "policies"]
    }

    /// A built-in preset spec.
    pub fn preset(name: &str) -> anyhow::Result<StudySpec> {
        let sexp = |mu: f64, delta: f64| BatchService::paper(ServiceSpec::shifted_exp(mu, delta));
        Ok(match name {
            // The CI smoke grid: one cluster, full spectrum, half-k
            // partial aggregation, three backends. The B = 1 row's k
            // axis canonicalizes to full completion, so the plan always
            // exercises dedup.
            "smoke" => StudySpec {
                n_workers: vec![12],
                services: vec![sexp(1.0, 0.2)],
                k_targets: vec![KTarget::Full, KTarget::Fraction(0.5)],
                backends: vec![BackendSel::Analytic, BackendSel::MonteCarlo, BackendSel::Des],
                mc_trials: 20_000,
                des_trials: 4_000,
                ..StudySpec::base("smoke")
            },
            // Fig. 2: E[T] vs B, one curve per ∆µ, theory and simulation.
            "fig2" => StudySpec {
                n_workers: vec![24],
                services: [0.05, 0.2, 0.5, 1.0, 2.0].iter().map(|&dm| sexp(1.0, dm)).collect(),
                backends: vec![BackendSel::Analytic, BackendSel::MonteCarlo],
                ..StudySpec::base("fig2")
            },
            // The mean–variance trade-off over a dense ∆µ grid (pure
            // closed forms: exercises the analytic memo grouping).
            "tradeoff" => StudySpec {
                n_workers: vec![24],
                services: (0..40).map(|i| sexp(1.0, 0.01 + 0.05 * i as f64)).collect(),
                backends: vec![BackendSel::Analytic],
                ..StudySpec::base("tradeoff")
            },
            // Theorem 1 policy comparison.
            "policies" => StudySpec {
                n_workers: vec![12],
                batches: BatchAxis::Explicit(vec![4]),
                policies: ReplicationPolicy::all().to_vec(),
                services: vec![
                    BatchService::paper(ServiceSpec::exp(1.0)),
                    sexp(1.0, 0.2),
                ],
                backends: vec![BackendSel::MonteCarlo, BackendSel::Analytic],
                mc_trials: 60_000,
                ..StudySpec::base("policies")
            },
            other => anyhow::bail!(
                "unknown study preset '{other}' (accepted: {})",
                Self::preset_names().join(", ")
            ),
        })
    }

    /// Resolve a CLI argument: a preset name, else a spec file path.
    pub fn load(arg: &str) -> anyhow::Result<StudySpec> {
        if Self::preset_names().contains(&arg) {
            return Self::preset(arg);
        }
        let path = std::path::Path::new(arg);
        if path.exists() {
            return Self::from_file(path);
        }
        anyhow::bail!(
            "unknown study '{arg}': neither a preset ({}) nor a spec file on disk",
            Self::preset_names().join(", ")
        )
    }

    /// Load a spec from a JSON file.
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<StudySpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading study spec {}: {e}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("study spec {}: {e}", path.display()))?;
        Self::from_json(&j)
    }

    /// Parse a spec from its JSON document. Errors name the offending
    /// field and value and list what is accepted.
    pub fn from_json(j: &Json) -> anyhow::Result<StudySpec> {
        let obj = j.as_object().ok_or_else(|| {
            anyhow::anyhow!(
                "study spec must be a JSON object (accepted fields: {})",
                SPEC_FIELDS.join(", ")
            )
        })?;
        for key in obj.keys() {
            anyhow::ensure!(
                SPEC_FIELDS.contains(&key.as_str()),
                "unknown study-spec field '{key}' (accepted: {})",
                SPEC_FIELDS.join(", ")
            );
        }
        let mut spec = StudySpec::base(json_str(obj, "name")?.unwrap_or("study"));

        let workers = json_arr(obj, "n_workers")?
            .ok_or_else(|| anyhow::anyhow!("study spec is missing required field 'n_workers'"))?;
        spec.n_workers = workers
            .iter()
            .map(|v| match v.as_i64() {
                Some(n) if n >= 1 => Ok(n as usize),
                _ => Err(spec_field_err("n_workers", "an array of positive integers", v)),
            })
            .collect::<anyhow::Result<_>>()?;

        if let Some(v) = obj.get("batches") {
            spec.batches = match v {
                Json::Str(s) if s == "feasible" => BatchAxis::Feasible,
                Json::Array(items) => BatchAxis::Explicit(
                    items
                        .iter()
                        .map(|x| match x.as_i64() {
                            Some(b) if b >= 1 => Ok(b as usize),
                            _ => Err(spec_field_err(
                                "batches",
                                "\"feasible\" or an array of positive integers",
                                x,
                            )),
                        })
                        .collect::<anyhow::Result<_>>()?,
                ),
                other => {
                    return Err(spec_field_err(
                        "batches",
                        "\"feasible\" or an array of positive integers",
                        other,
                    ))
                }
            };
        }

        if let Some(items) = json_arr(obj, "policies")? {
            spec.policies = items
                .iter()
                .map(|v| {
                    let s = v
                        .as_str()
                        .ok_or_else(|| spec_field_err("policies", "an array of policy names", v))?;
                    ReplicationPolicy::parse(s)
                        .map_err(|e| anyhow::anyhow!("study-spec field 'policies': {e}"))
                })
                .collect::<anyhow::Result<_>>()?;
        }

        let model = match json_str(obj, "batch_model")? {
            None => BatchModel::SizeScaled,
            Some(s) => BatchModel::parse(s)
                .map_err(|e| anyhow::anyhow!("study-spec field 'batch_model': {e}"))?,
        };
        let services = json_arr(obj, "services")?
            .ok_or_else(|| anyhow::anyhow!("study spec is missing required field 'services'"))?;
        spec.services = services
            .iter()
            .map(|v| {
                let s = v.as_str().ok_or_else(|| {
                    spec_field_err("services", "an array of service spec strings", v)
                })?;
                let parsed = ServiceSpec::parse(s)
                    .map_err(|e| anyhow::anyhow!("study-spec field 'services': {e}"))?;
                Ok(BatchService { spec: parsed, model })
            })
            .collect::<anyhow::Result<_>>()?;

        if let Some(items) = json_arr(obj, "redundancy")? {
            spec.redundancy = items
                .iter()
                .map(|v| {
                    let s = v.as_str().ok_or_else(|| {
                        spec_field_err("redundancy", "an array of redundancy-mode strings", v)
                    })?;
                    RedundancyAxis::parse(s)
                        .map_err(|e| anyhow::anyhow!("study-spec field 'redundancy': {e}"))
                })
                .collect::<anyhow::Result<_>>()?;
        }

        if let Some(items) = json_arr(obj, "k_of_b")? {
            spec.k_targets = items
                .iter()
                .map(|v| match v {
                    Json::Str(s) if s == "full" => Ok(KTarget::Full),
                    Json::Str(s) if s.starts_with("k:") || s.starts_with("frac:") => {
                        KTarget::parse(s)
                            .map_err(|e| anyhow::anyhow!("study-spec field 'k_of_b': {e}"))
                    }
                    Json::Num(x) if *x > 0.0 && *x < 1.0 => Ok(KTarget::Fraction(*x)),
                    // The bare number 1 is ambiguous (k = 1 vs the
                    // fraction 1.0 = every batch): force the explicit
                    // spelling rather than silently flipping semantics.
                    Json::Num(x) if *x == 1.0 => Err(anyhow::anyhow!(
                        "study-spec field 'k_of_b': 1 is ambiguous — write \"full\" to \
                         wait for every batch or \"k:1\" to wait for the single \
                         earliest batch"
                    )),
                    Json::Num(x) if x.fract() == 0.0 && *x >= 2.0 => {
                        Ok(KTarget::Exact(*x as usize))
                    }
                    other => Err(spec_field_err(
                        "k_of_b",
                        "\"full\", \"k:N\", \"frac:F\", a fraction in (0, 1), or an \
                         integer k >= 2",
                        other,
                    )),
                })
                .collect::<anyhow::Result<_>>()?;
        }

        if let Some(items) = json_arr(obj, "speeds")? {
            spec.speeds = items
                .iter()
                .map(|v| match v {
                    Json::Str(s) => SpeedAxis::parse(s)
                        .map_err(|e| anyhow::anyhow!("study-spec field 'speeds': {e}")),
                    Json::Array(xs) => {
                        let factors = xs
                            .iter()
                            .map(|x| {
                                x.as_f64().ok_or_else(|| {
                                    spec_field_err("speeds", "arrays of per-worker factors", x)
                                })
                            })
                            .collect::<anyhow::Result<Vec<f64>>>()?;
                        Ok(SpeedAxis::Explicit(factors))
                    }
                    other => Err(spec_field_err(
                        "speeds",
                        "\"homogeneous\", \"ramp:LO,HI\", or an array of factors",
                        other,
                    )),
                })
                .collect::<anyhow::Result<_>>()?;
        }

        if let Some(items) = json_arr(obj, "backends")? {
            spec.backends = items
                .iter()
                .map(|v| {
                    let s = v.as_str().ok_or_else(|| {
                        spec_field_err("backends", "an array of backend names", v)
                    })?;
                    BackendSel::parse(s)
                        .map_err(|e| anyhow::anyhow!("study-spec field 'backends': {e}"))
                })
                .collect::<anyhow::Result<_>>()?;
        }

        if let Some(m) = json_int(obj, "verify_m")? {
            anyhow::ensure!(
                m >= 0,
                "study-spec field 'verify_m': expected a non-negative integer \
                 (0 or 1 = off, m >= 2 = vote size), got {m}"
            );
            spec.verify_m = m as usize;
        }
        if let Some(t) = json_int(obj, "mc_trials")? {
            spec.mc_trials = t.max(0) as u64;
        }
        if let Some(t) = json_int(obj, "des_trials")? {
            spec.des_trials = t.max(0) as u64;
        }
        if let Some(t) = json_int(obj, "live_rounds")? {
            spec.live_rounds = t.max(0) as u64;
        }
        if let Some(b) = json_bool(obj, "des_cancellation")? {
            spec.des_cancellation = b;
        }
        if let Some(v) = obj.get("live") {
            let lobj = v.as_object().ok_or_else(|| {
                spec_field_err(
                    "live",
                    &format!("an object with keys {}", LIVE_FIELDS.join(", ")),
                    v,
                )
            })?;
            for key in lobj.keys() {
                anyhow::ensure!(
                    LIVE_FIELDS.contains(&key.as_str()),
                    "unknown study-spec field 'live.{key}' (accepted: {})",
                    LIVE_FIELDS.join(", ")
                );
            }
            if let Some(x) = lobj.get("time_scale") {
                spec.live.time_scale = x
                    .as_f64()
                    .filter(|t| *t > 0.0)
                    .ok_or_else(|| spec_field_err("live.time_scale", "a positive number", x))?;
            }
            if let Some(n) = json_int(lobj, "n_samples")? {
                spec.live.n_samples = n.max(1) as usize;
            }
            if let Some(d) = json_int(lobj, "dim")? {
                spec.live.dim = d.max(1) as usize;
            }
            if let Some(p) = json_bool(lobj, "pjrt")? {
                spec.live.pjrt = p;
            }
            if let Some(c) = json_bool(lobj, "cancellation")? {
                spec.live.cancellation = c;
            }
            if let Some(a) = json_str(lobj, "artifacts_dir")? {
                spec.live.artifacts_dir = Some(a.to_string());
            }
        }
        if let Some(s) = json_int(obj, "seed")? {
            spec.seed = s as u64;
        }
        if let Some(q) = json_bool(obj, "quantiles")? {
            spec.quantiles = q;
        }
        if let Some(c) = json_bool(obj, "cost")? {
            spec.cost = c;
        }
        Ok(spec)
    }
}

// ---------------------------------------------------------------------
// The compiled plan
// ---------------------------------------------------------------------

/// One unique evaluation cell: a scenario under one backend at one
/// trial budget. Evaluated once, fanned out to every axis point that
/// references it.
#[derive(Debug, Clone)]
pub struct PlannedCell {
    /// The fully self-describing scenario (seed derived from the
    /// canonical key).
    pub scenario: Scenario,
    /// The backend that evaluates it.
    pub backend: BackendSel,
    /// Trial/round budget (0 for the analytic backend).
    pub trials: u64,
    /// Canonical cell key (the dedup identity; stable across runs).
    pub key: String,
}

/// Axis coordinates of one point of the study grid.
#[derive(Debug, Clone, PartialEq)]
pub struct PointCoords {
    /// Cluster size.
    pub n: usize,
    /// Requested batch count (axis value, before policy normalization).
    pub b: usize,
    /// The scenario's actual batch count (e.g. 1 under `FullDiversity`).
    pub eff_b: usize,
    /// Replication policy.
    pub policy: ReplicationPolicy,
    /// Index into `StudySpec::services`.
    pub service_idx: usize,
    /// Service key (`spec-name/model-name`).
    pub service: String,
    /// Index into `StudySpec::redundancy`.
    pub redundancy_idx: usize,
    /// Redundancy label (`upfront`, `speculative:F`).
    pub redundancy: String,
    /// Index into `StudySpec::k_targets`.
    pub k_idx: usize,
    /// Resolved partial-aggregation target (`None` = full completion).
    pub k_of_b: Option<usize>,
    /// Index into `StudySpec::speeds`.
    pub speeds_idx: usize,
    /// Canonical speed key (`homogeneous` or the resolved factor vector).
    pub speeds: String,
    /// Backend of this point.
    pub backend: BackendSel,
}

/// One axis point of the compiled grid and the cell that serves it.
#[derive(Debug, Clone)]
pub struct PlannedPoint {
    /// The point's axis coordinates.
    pub coords: PointCoords,
    /// Index into [`ExecutionPlan::cells`] / [`StudyReport`]'s cells.
    pub cell: usize,
}

/// A compiled, deduplicated study: unique cells plus the point→cell
/// fan-out map.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    /// The spec this plan was compiled from.
    pub spec: StudySpec,
    /// Unique structural scenarios in first-seen (canonical) order —
    /// the shared grid vocabulary the conformance matrix enumerates.
    pub scenarios: Vec<Scenario>,
    /// Unique `(scenario, backend, trials)` cells in first-seen order.
    pub cells: Vec<PlannedCell>,
    /// Every axis point, mapped onto its cell.
    pub points: Vec<PlannedPoint>,
}

impl ExecutionPlan {
    /// Number of axis points the grid spans.
    pub fn axis_points(&self) -> usize {
        self.points.len()
    }

    /// Axis points served by an already-planned cell (the dedup win).
    pub fn deduped_points(&self) -> usize {
        self.points.len() - self.cells.len()
    }

    /// Number of cells a backend contributes.
    pub fn backend_cells(&self, backend: BackendSel) -> usize {
        self.cells.iter().filter(|c| c.backend == backend).count()
    }
}

// ---------------------------------------------------------------------
// Spec-file field helpers
// ---------------------------------------------------------------------

/// Typed study-spec field error: names the field, what was expected,
/// and echoes the offending value.
fn spec_field_err(field: &str, want: &str, got: &Json) -> anyhow::Error {
    anyhow::anyhow!("study-spec field '{field}': expected {want}, got {got}")
}

fn json_str<'a>(
    obj: &'a BTreeMap<String, Json>,
    field: &str,
) -> anyhow::Result<Option<&'a str>> {
    match obj.get(field) {
        None => Ok(None),
        Some(v) => v.as_str().map(Some).ok_or_else(|| spec_field_err(field, "a string", v)),
    }
}

fn json_int(obj: &BTreeMap<String, Json>, field: &str) -> anyhow::Result<Option<i64>> {
    match obj.get(field) {
        None => Ok(None),
        Some(v) => v.as_i64().map(Some).ok_or_else(|| spec_field_err(field, "an integer", v)),
    }
}

fn json_bool(obj: &BTreeMap<String, Json>, field: &str) -> anyhow::Result<Option<bool>> {
    match obj.get(field) {
        None => Ok(None),
        Some(Json::Bool(b)) => Ok(Some(*b)),
        Some(v) => Err(spec_field_err(field, "a bool", v)),
    }
}

fn json_arr<'a>(
    obj: &'a BTreeMap<String, Json>,
    field: &str,
) -> anyhow::Result<Option<&'a [Json]>> {
    match obj.get(field) {
        None => Ok(None),
        Some(v) => v.as_array().map(Some).ok_or_else(|| spec_field_err(field, "an array", v)),
    }
}

// ---------------------------------------------------------------------
// Canonicalization helpers
// ---------------------------------------------------------------------

/// The batch count a policy actually produces for a requested `(n, b)`.
fn effective_batches(policy: ReplicationPolicy, n: usize, b: usize) -> usize {
    match policy {
        ReplicationPolicy::FullDiversity => 1,
        ReplicationPolicy::FullParallelism => n,
        // One cyclic window per worker.
        ReplicationPolicy::OverlappingCyclic => n,
        _ => b,
    }
}

/// Content-stable service key: the compact spec name plus the batch
/// model. Trace specs append a content hash, because their display name
/// only carries the sample count.
fn service_key(svc: &BatchService) -> String {
    match &svc.spec {
        ServiceSpec::Trace { samples } => {
            let h = crate::util::rng::fnv1a(
                samples.iter().flat_map(|x| x.to_bits().to_le_bytes()),
            );
            format!("trace[{};{h:016x}]/{}", samples.len(), svc.model.name())
        }
        spec => format!("{}/{}", spec.name(), svc.model.name()),
    }
}

/// FNV-1a over the canonical key, folded with the root seed through
/// SplitMix64 — a deterministic, well-mixed per-scenario seed.
fn derive_seed(root: u64, key: &str) -> u64 {
    let mut state = crate::util::rng::fnv1a(key.bytes()) ^ root.rotate_left(17);
    crate::util::rng::splitmix64(&mut state)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sexp_paper() -> BatchService {
        BatchService::paper(ServiceSpec::shifted_exp(1.0, 0.2))
    }

    #[test]
    fn compile_dedups_duplicate_axis_points() {
        // Duplicate axis entries (the same batch count requested three
        // times, under two backends) plan one cell per unique
        // (scenario, backend, trials) triple and fan it out.
        let spec = StudySpec {
            n_workers: vec![12],
            batches: BatchAxis::Explicit(vec![4, 4, 2, 4]),
            services: vec![sexp_paper()],
            backends: vec![BackendSel::MonteCarlo, BackendSel::Analytic],
            mc_trials: 100,
            ..StudySpec::base("dedup-test")
        };
        let plan = spec.compile().unwrap();
        assert_eq!(plan.points.len(), 8, "4 batch entries × 2 backends");
        assert_eq!(plan.cells.len(), 4, "2 unique scenarios × 2 backends");
        assert_eq!(plan.deduped_points(), 4);
        assert_eq!(plan.scenarios.len(), 2);
        assert_eq!(plan.backend_cells(BackendSel::MonteCarlo), 2);
        assert_eq!(plan.backend_cells(BackendSel::Analytic), 2);
        // Duplicate points reference the same cell index.
        let b4_mc: Vec<usize> = plan
            .points
            .iter()
            .filter(|p| p.coords.b == 4 && p.coords.backend == BackendSel::MonteCarlo)
            .map(|p| p.cell)
            .collect();
        assert_eq!(b4_mc.len(), 3);
        assert!(b4_mc.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn canonicalization_collapses_equivalent_axes() {
        let spec = StudySpec {
            n_workers: vec![12],
            batches: BatchAxis::Explicit(vec![4]),
            services: vec![sexp_paper()],
            k_targets: vec![KTarget::Full, KTarget::Fraction(1.0), KTarget::Exact(4)],
            speeds: vec![
                SpeedAxis::Homogeneous,
                SpeedAxis::Ramp { lo: 1.0, hi: 1.0 },
                SpeedAxis::Explicit(vec![1.0; 12]),
            ],
            backends: vec![BackendSel::MonteCarlo],
            mc_trials: 100,
            ..StudySpec::base("canon-test")
        };
        let plan = spec.compile().unwrap();
        // 3 k entries × 3 speed entries = 9 axis points, all one cell.
        assert_eq!(plan.points.len(), 9);
        assert_eq!(plan.cells.len(), 1);
        assert_eq!(plan.deduped_points(), 8);
        assert_eq!(plan.scenarios.len(), 1);
        assert!(plan.scenarios[0].k_of_b.is_none());
        assert!(plan.scenarios[0].worker_speeds.is_none());
        // Every point keeps its own axis coordinates despite sharing
        // the cell.
        assert!(plan.points.iter().any(|p| p.coords.k_idx == 2));
        assert!(plan.points.iter().any(|p| p.coords.speeds_idx == 1));
        for p in &plan.points {
            assert_eq!(p.cell, 0);
        }
    }

    #[test]
    fn overlapping_k_equals_b_is_not_canonicalized() {
        // Full completion for an overlapping layout is the coverage
        // rule, which can fire before every window finishes — waiting
        // for the B-th window is a strictly different (later) event, so
        // the planner must keep it a distinct cell.
        let spec = StudySpec {
            n_workers: vec![8],
            batches: BatchAxis::Explicit(vec![4]),
            policies: vec![ReplicationPolicy::OverlappingCyclic],
            services: vec![sexp_paper()],
            // eff_b for the overlapping layout is N = 8 windows.
            k_targets: vec![KTarget::Full, KTarget::Exact(8)],
            backends: vec![BackendSel::MonteCarlo],
            mc_trials: 100,
            ..StudySpec::base("overlap-k-canon")
        };
        let plan = spec.compile().unwrap();
        assert_eq!(plan.cells.len(), 2, "coverage vs all-windows are distinct cells");
        assert_eq!(plan.scenarios[0].k_of_b, None);
        assert_eq!(plan.scenarios[1].k_of_b, Some(8));
        // The same k = B axis on a disjoint policy collapses onto the
        // full-completion cell.
        let spec = StudySpec {
            policies: vec![ReplicationPolicy::BalancedDisjoint],
            k_targets: vec![KTarget::Full, KTarget::Exact(4)],
            ..spec
        };
        let plan = spec.compile().unwrap();
        assert_eq!(plan.cells.len(), 1);
    }

    #[test]
    fn b_insensitive_policies_canonicalize_the_batch_axis() {
        // FullDiversity is one batch and FullParallelism is N batches
        // whatever b the axis requests: the whole feasible-b axis must
        // collapse to one cell per policy, while BalancedDisjoint keeps
        // one cell per b. OverlappingCyclic keeps b too (window = N/b).
        let spec = StudySpec {
            n_workers: vec![12],
            policies: vec![
                ReplicationPolicy::FullDiversity,
                ReplicationPolicy::FullParallelism,
                ReplicationPolicy::BalancedDisjoint,
            ],
            services: vec![sexp_paper()],
            backends: vec![BackendSel::MonteCarlo],
            mc_trials: 100,
            ..StudySpec::base("policy-b-canon")
        };
        let plan = spec.compile().unwrap();
        let n_b = crate::assignment::feasible_batch_counts(12).len();
        assert_eq!(plan.points.len(), 3 * n_b);
        assert_eq!(plan.cells.len(), 2 + n_b, "one FD cell + one FP cell + n_b balanced");
        let cells_of = |p: ReplicationPolicy| {
            let mut v: Vec<usize> = plan
                .points
                .iter()
                .filter(|pt| pt.coords.policy == p)
                .map(|pt| pt.cell)
                .collect();
            v.dedup();
            v.len()
        };
        assert_eq!(cells_of(ReplicationPolicy::FullDiversity), 1);
        assert_eq!(cells_of(ReplicationPolicy::FullParallelism), 1);
        assert_eq!(cells_of(ReplicationPolicy::BalancedDisjoint), n_b);
    }

    #[test]
    fn derived_seeds_are_stable_and_distinct() {
        let spec = StudySpec {
            n_workers: vec![12],
            batches: BatchAxis::Explicit(vec![2, 4]),
            services: vec![sexp_paper()],
            backends: vec![BackendSel::MonteCarlo],
            mc_trials: 100,
            ..StudySpec::base("seed-test")
        };
        let a = spec.compile().unwrap();
        let b = spec.compile().unwrap();
        assert_eq!(a.scenarios.len(), 2);
        assert_eq!(a.scenarios[0].seed, b.scenarios[0].seed, "seeds are reproducible");
        assert_ne!(a.scenarios[0].seed, a.scenarios[1].seed, "cells draw distinct seeds");
        // A different root seed moves every derived seed.
        let other = StudySpec { seed: 43, ..spec }.compile().unwrap();
        assert_ne!(other.scenarios[0].seed, a.scenarios[0].seed);
    }

    #[test]
    fn compile_errors_name_the_offending_field() {
        let base = StudySpec {
            n_workers: vec![12],
            services: vec![sexp_paper()],
            backends: vec![BackendSel::MonteCarlo],
            ..StudySpec::base("err-test")
        };
        let msg = StudySpec { services: vec![], ..base.clone() }
            .compile()
            .unwrap_err()
            .to_string();
        assert!(msg.contains("StudySpec::services"), "{msg}");
        let msg = StudySpec {
            k_targets: vec![KTarget::Exact(9)],
            batches: BatchAxis::Explicit(vec![4]),
            ..base.clone()
        }
        .compile()
        .unwrap_err()
        .to_string();
        assert!(msg.contains("StudySpec::k_targets[0]"), "{msg}");
        assert!(msg.contains("k=9"), "{msg}");
        let msg = StudySpec { mc_trials: 0, ..base.clone() }
            .compile()
            .unwrap_err()
            .to_string();
        assert!(msg.contains("mc_trials"), "{msg}");
        let msg = StudySpec {
            speeds: vec![SpeedAxis::Explicit(vec![1.0; 3])],
            ..base
        }
        .compile()
        .unwrap_err()
        .to_string();
        assert!(msg.contains("StudySpec::speeds[0]"), "{msg}");
        assert!(msg.contains("12 workers"), "{msg}");
    }

    #[test]
    fn spec_json_round_trip_and_errors() {
        let j = Json::parse(
            r#"{
                "name": "from-json",
                "n_workers": [12, 24],
                "batches": [2, 4],
                "policies": ["balanced_disjoint", "full_diversity"],
                "services": ["sexp:1.0,0.2", "exp:1.0"],
                "redundancy": ["upfront", "speculative:1.5"],
                "k_of_b": ["full", 0.5, 2],
                "speeds": ["homogeneous", "ramp:0.5,2.0", [1.0, 2.0]],
                "backends": ["analytic", "montecarlo"],
                "mc_trials": 5000,
                "seed": 7,
                "quantiles": false
            }"#,
        )
        .unwrap();
        let spec = StudySpec::from_json(&j).unwrap();
        assert_eq!(spec.name, "from-json");
        assert_eq!(spec.n_workers, vec![12, 24]);
        assert_eq!(spec.batches, BatchAxis::Explicit(vec![2, 4]));
        assert_eq!(spec.policies.len(), 2);
        assert_eq!(spec.services.len(), 2);
        assert_eq!(spec.redundancy[1], RedundancyAxis::Speculative(1.5));
        assert_eq!(spec.k_targets, vec![KTarget::Full, KTarget::Fraction(0.5), KTarget::Exact(2)]);
        assert_eq!(spec.speeds.len(), 3);
        assert_eq!(spec.backends, vec![BackendSel::Analytic, BackendSel::MonteCarlo]);
        assert_eq!(spec.mc_trials, 5000);
        assert_eq!(spec.seed, 7);
        assert!(!spec.quantiles && spec.cost);

        // Unknown fields are named and the accepted list is printed.
        let bad = Json::parse(r#"{"n_workers": [4], "services": ["exp:1"], "nope": 1}"#).unwrap();
        let msg = StudySpec::from_json(&bad).unwrap_err().to_string();
        assert!(msg.contains("'nope'"), "{msg}");
        assert!(msg.contains("n_workers") && msg.contains("backends"), "{msg}");
        // Wrong value types name the field and echo the value.
        let bad = Json::parse(r#"{"n_workers": "x", "services": ["exp:1"]}"#).unwrap();
        let msg = StudySpec::from_json(&bad).unwrap_err().to_string();
        assert!(msg.contains("'n_workers'") && msg.contains("\"x\""), "{msg}");
        // The engine/live knobs are reachable from spec files.
        let knobs = Json::parse(
            r#"{"n_workers": [4], "services": ["exp:1"],
                "backends": ["des", "live"], "des_cancellation": false,
                "live": {"time_scale": 0.01, "n_samples": 128, "dim": 8,
                         "cancellation": false}}"#,
        )
        .unwrap();
        let spec_k = StudySpec::from_json(&knobs).unwrap();
        assert!(!spec_k.des_cancellation);
        assert_eq!(spec_k.live.time_scale, 0.01);
        assert_eq!(spec_k.live.n_samples, 128);
        assert_eq!(spec_k.live.dim, 8);
        assert!(!spec_k.live.cancellation && !spec_k.live.pjrt);
        // Unknown nested live keys are named with the accepted list.
        let bad = Json::parse(
            r#"{"n_workers": [4], "services": ["exp:1"], "live": {"speed": 1}}"#,
        )
        .unwrap();
        let msg = StudySpec::from_json(&bad).unwrap_err().to_string();
        assert!(msg.contains("'live.speed'") && msg.contains("time_scale"), "{msg}");

        // The ambiguous bare 1 is rejected with both spellings offered;
        // the explicit label forms parse.
        let bad = Json::parse(r#"{"n_workers": [4], "services": ["exp:1"], "k_of_b": [1]}"#)
            .unwrap();
        let msg = StudySpec::from_json(&bad).unwrap_err().to_string();
        assert!(msg.contains("ambiguous"), "{msg}");
        assert!(msg.contains("\"full\"") && msg.contains("\"k:1\""), "{msg}");
        let labeled = Json::parse(
            r#"{"n_workers": [4], "services": ["exp:1"], "k_of_b": ["k:1", "frac:0.75"]}"#,
        )
        .unwrap();
        let spec_l = StudySpec::from_json(&labeled).unwrap();
        assert_eq!(spec_l.k_targets, vec![KTarget::Exact(1), KTarget::Fraction(0.75)]);
        // Bad enum values list what is accepted.
        let bad =
            Json::parse(r#"{"n_workers": [4], "services": ["exp:1"], "backends": ["speedy"]}"#)
                .unwrap();
        let msg = StudySpec::from_json(&bad).unwrap_err().to_string();
        assert!(msg.contains("'speedy'") && msg.contains("montecarlo"), "{msg}");
        let bad = Json::parse(
            r#"{"n_workers": [4], "services": ["exp:1"], "policies": ["fancy"]}"#,
        )
        .unwrap();
        let msg = StudySpec::from_json(&bad).unwrap_err().to_string();
        assert!(msg.contains("'fancy'") && msg.contains("balanced_disjoint"), "{msg}");
    }

    #[test]
    fn presets_compile() {
        for name in StudySpec::preset_names() {
            let spec = StudySpec::preset(name).unwrap().fast();
            let plan = spec.compile().unwrap();
            assert!(!plan.cells.is_empty(), "preset {name} plans no cells");
            assert!(plan.points.len() >= plan.cells.len());
        }
        let msg = StudySpec::preset("nope").unwrap_err().to_string();
        assert!(msg.contains("smoke"), "{msg}");
        // The smoke preset always exercises dedup: the B = 1 row's
        // half-k target canonicalizes onto the full-completion cell.
        let plan = StudySpec::preset("smoke").unwrap().compile().unwrap();
        assert!(plan.deduped_points() > 0, "{:?}", plan.deduped_points());
    }

    #[test]
    fn verify_m_knob_compiles_gates_and_keys() {
        let base = StudySpec {
            n_workers: vec![12],
            batches: BatchAxis::Explicit(vec![4]),
            services: vec![sexp_paper()],
            backends: vec![BackendSel::MonteCarlo],
            mc_trials: 100,
            ..StudySpec::base("verify-knob")
        };
        let off = base.clone().compile().unwrap();
        assert_eq!(off.scenarios[0].verify_m, None);
        let on = StudySpec { verify_m: 2, ..base.clone() }.compile().unwrap();
        assert_eq!(on.scenarios[0].verify_m, Some(2));
        // The verify segment joins the canonical key, so the derived
        // scenario seed moves with it.
        assert_ne!(on.scenarios[0].seed, off.scenarios[0].seed);
        // verify_m = 1 is the off spelling: legacy keys (and seeds)
        // stay byte-stable.
        let one = StudySpec { verify_m: 1, ..base.clone() }.compile().unwrap();
        assert_eq!(one.scenarios[0].verify_m, None);
        assert_eq!(one.scenarios[0].seed, off.scenarios[0].seed);
        // Infeasible m (FullParallelism has replication degree 1) names
        // the knob and the axis point.
        let msg = StudySpec {
            policies: vec![ReplicationPolicy::FullParallelism],
            verify_m: 2,
            ..base.clone()
        }
        .compile()
        .unwrap_err()
        .to_string();
        assert!(msg.contains("StudySpec::verify_m"), "{msg}");
        assert!(msg.contains("full_parallelism"), "{msg}");
        // Speculative redundancy is refused before any cell is planned.
        let msg = StudySpec {
            redundancy: vec![RedundancyAxis::Speculative(1.5)],
            verify_m: 2,
            ..base.clone()
        }
        .compile()
        .unwrap_err()
        .to_string();
        assert!(msg.contains("StudySpec::verify_m") && msg.contains("upfront"), "{msg}");
        // The spec-file field parses, and junk is rejected with the
        // off/on semantics spelled out.
        let j = Json::parse(
            r#"{"n_workers": [12], "services": ["sexp:1.0,0.2"], "verify_m": 2}"#,
        )
        .unwrap();
        assert_eq!(StudySpec::from_json(&j).unwrap().verify_m, 2);
        let bad = Json::parse(
            r#"{"n_workers": [12], "services": ["sexp:1.0,0.2"], "verify_m": -1}"#,
        )
        .unwrap();
        let msg = StudySpec::from_json(&bad).unwrap_err().to_string();
        assert!(msg.contains("'verify_m'"), "{msg}");
    }

    #[test]
    fn trace_specs_key_by_content() {
        use std::sync::Arc;
        let a = BatchService::paper(ServiceSpec::Trace { samples: Arc::new(vec![1.0, 2.0]) });
        let b = BatchService::paper(ServiceSpec::Trace { samples: Arc::new(vec![1.0, 3.0]) });
        assert_ne!(service_key(&a), service_key(&b));
        assert_eq!(service_key(&a), service_key(&a.clone()));
    }
}
