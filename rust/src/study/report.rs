//! Study results: per-cell outcomes, point lookups for the experiment
//! drivers, and the versioned `STUDY` JSON artifact (schema-validated
//! like the `BENCH_*.json` trajectories) plus CSV emit for plotting.

use super::{BackendSel, PlannedPoint, PointCoords};
use crate::evaluator::CompletionStats;
use crate::util::json::Json;
use crate::util::table::{fmt_f, Table};
use std::path::Path;

/// Schema version of the study artifact.
pub const SCHEMA_VERSION: i64 = 1;

/// What one cell produced.
#[derive(Debug, Clone)]
pub enum CellOutcome {
    /// The backend's statistics.
    Stats(CompletionStats),
    /// The backend refused the scenario (its own message, naming the
    /// offending `Scenario` field and value).
    Refused(String),
}

/// One evaluated (or refused) cell of a study.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// Canonical cell key (matches the plan's `PlannedCell::key`).
    pub key: String,
    /// Backend that served the cell.
    pub backend: BackendSel,
    /// Trial/round budget (0 for analytic cells).
    pub trials: u64,
    /// Statistics or refusal.
    pub outcome: CellOutcome,
}

impl CellResult {
    /// The statistics, when the cell was served.
    pub fn stats(&self) -> Option<&CompletionStats> {
        match &self.outcome {
            CellOutcome::Stats(st) => Some(st),
            CellOutcome::Refused(_) => None,
        }
    }

    /// The refusal message, when the backend declined the scenario.
    pub fn refusal(&self) -> Option<&str> {
        match &self.outcome {
            CellOutcome::Refused(msg) => Some(msg),
            CellOutcome::Stats(_) => None,
        }
    }
}

/// The collected result of one executed study. Bit-deterministic per
/// `(spec, seed)` for any thread count (live cells excepted — they
/// measure wall clock).
#[derive(Debug, Clone)]
pub struct StudyReport {
    /// Study name.
    pub name: String,
    /// Root seed of the spec.
    pub seed: u64,
    /// Whether quantiles were requested (gates artifact/CSV emit).
    pub quantiles: bool,
    /// Whether redundancy cost was requested (gates artifact/CSV emit).
    pub cost: bool,
    /// Axis points the grid spanned.
    pub axis_points: u64,
    /// Unique cells evaluated.
    pub unique_cells: u64,
    /// Axis points served by an already-evaluated cell (dedup savings).
    pub deduped_points: u64,
    /// Cells refused by their backend.
    pub refused_cells: u64,
    /// Every axis point, mapped onto its cell index.
    pub points: Vec<PlannedPoint>,
    /// Cell outcomes, in plan (canonical first-seen) order.
    pub cells: Vec<CellResult>,
}

impl StudyReport {
    /// The cell serving one planned point.
    pub fn cell_of(&self, point: &PlannedPoint) -> &CellResult {
        &self.cells[point.cell]
    }

    /// First point whose coordinates match the predicate.
    pub fn point_where(
        &self,
        f: &dyn Fn(&PointCoords) -> bool,
    ) -> Option<&PlannedPoint> {
        self.points.iter().find(|p| f(&p.coords))
    }

    /// Statistics of the first matching point; `None` when no point
    /// matches or its backend refused the cell.
    pub fn try_stats_where(
        &self,
        f: &dyn Fn(&PointCoords) -> bool,
    ) -> Option<&CompletionStats> {
        self.point_where(f).and_then(|p| self.cell_of(p).stats())
    }

    /// Statistics of the first matching point; errors (naming the cell
    /// and any refusal) when missing.
    pub fn stats_where(
        &self,
        f: &dyn Fn(&PointCoords) -> bool,
    ) -> anyhow::Result<&CompletionStats> {
        let p = self
            .point_where(f)
            .ok_or_else(|| anyhow::anyhow!("no study point matches the predicate"))?;
        let cell = self.cell_of(p);
        cell.stats().ok_or_else(|| {
            anyhow::anyhow!(
                "study cell '{}' was refused by its backend: {}",
                cell.key,
                cell.refusal().unwrap_or("(no message)")
            )
        })
    }

    /// Serialize to the versioned artifact schema (see `README.md`
    /// §Running studies).
    pub fn to_json(&self) -> Json {
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let mut pairs: Vec<(&str, Json)> = vec![
                    ("key", c.key.as_str().into()),
                    ("backend", c.backend.name().into()),
                    ("trials", (c.trials as i64).into()),
                ];
                match &c.outcome {
                    CellOutcome::Refused(msg) => pairs.push(("refused", msg.as_str().into())),
                    CellOutcome::Stats(st) => {
                        pairs.push(("mean", st.mean.into()));
                        pairs.push(("variance", st.variance.into()));
                        pairs.push(("sem", st.sem.into()));
                        pairs.push(("samples", (st.samples as i64).into()));
                        if self.quantiles && !st.quantiles.is_empty() {
                            pairs.push((
                                "quantiles",
                                Json::Array(
                                    st.quantiles
                                        .iter()
                                        .map(|&(q, t)| {
                                            Json::Array(vec![Json::Num(q), Json::Num(t)])
                                        })
                                        .collect(),
                                ),
                            ));
                        }
                        if self.cost {
                            if let Some(cost) = &st.cost {
                                pairs.push((
                                    "cost",
                                    Json::obj(vec![
                                        ("busy", cost.busy.into()),
                                        ("wasted", cost.wasted.into()),
                                    ]),
                                ));
                            }
                        }
                        if let Some(ov) = &st.overhead {
                            pairs.push((
                                "overhead",
                                Json::obj(vec![
                                    ("dispatch_s", ov.dispatch_s.into()),
                                    ("wall_s", ov.wall_s.into()),
                                    ("injected_s", ov.injected_s.into()),
                                    ("overhead_s", ov.overhead_s().into()),
                                ]),
                            ));
                        }
                    }
                }
                Json::obj(pairs)
            })
            .collect();
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                let c = &p.coords;
                Json::obj(vec![
                    ("cell", (p.cell as i64).into()),
                    ("n", c.n.into()),
                    ("b", c.b.into()),
                    ("eff_b", c.eff_b.into()),
                    ("policy", c.policy.name().into()),
                    ("service", c.service.as_str().into()),
                    ("redundancy", c.redundancy.as_str().into()),
                    (
                        "k_of_b",
                        c.k_of_b.map(|k| Json::from(k as i64)).unwrap_or(Json::Null),
                    ),
                    ("speeds", c.speeds.as_str().into()),
                    ("backend", c.backend.name().into()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", SCHEMA_VERSION.into()),
            ("study", self.name.as_str().into()),
            ("seed", (self.seed as i64).into()),
            ("axis_points", (self.axis_points as i64).into()),
            ("unique_cells", (self.unique_cells as i64).into()),
            ("deduped_points", (self.deduped_points as i64).into()),
            ("refused_cells", (self.refused_cells as i64).into()),
            ("cells", Json::Array(cells)),
            ("points", Json::Array(points)),
        ])
    }

    /// Write the artifact to `path`.
    pub fn write(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }

    /// Render one CSV row per axis point (coordinates + stats) for
    /// plotting.
    pub fn to_csv(&self) -> String {
        let mut t = Table::new(
            &self.name,
            &[
                "n", "b", "eff_b", "policy", "service", "redundancy", "k_of_b", "speeds",
                "backend", "trials", "mean", "variance", "sem", "samples", "p50", "p99",
                "busy", "wasted", "refused",
            ],
        );
        for p in &self.points {
            let c = &p.coords;
            let cell = self.cell_of(p);
            let (mean, variance, sem, samples, p50, p99, busy, wasted, refused) =
                match &cell.outcome {
                    CellOutcome::Refused(msg) => (
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "0".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        msg.clone(),
                    ),
                    CellOutcome::Stats(st) => {
                        let q = |q: f64| {
                            if self.quantiles {
                                st.quantile(q)
                                    .map(|v| fmt_f(v, 6))
                                    .unwrap_or_else(|| "-".into())
                            } else {
                                "-".into()
                            }
                        };
                        let (busy, wasted) = match (&st.cost, self.cost) {
                            (Some(cost), true) => (fmt_f(cost.busy, 6), fmt_f(cost.wasted, 6)),
                            _ => ("-".into(), "-".into()),
                        };
                        (
                            fmt_f(st.mean, 6),
                            fmt_f(st.variance, 6),
                            fmt_f(st.sem, 6),
                            st.samples.to_string(),
                            q(0.5),
                            q(0.99),
                            busy,
                            wasted,
                            String::new(),
                        )
                    }
                };
            t.row(vec![
                c.n.to_string(),
                c.b.to_string(),
                c.eff_b.to_string(),
                c.policy.name().to_string(),
                c.service.clone(),
                c.redundancy.clone(),
                c.k_of_b.map(|k| k.to_string()).unwrap_or_else(|| "full".into()),
                c.speeds.clone(),
                c.backend.name().to_string(),
                cell.trials.to_string(),
                mean,
                variance,
                sem,
                samples,
                p50,
                p99,
                busy,
                wasted,
                refused,
            ]);
        }
        t.to_csv()
    }

    /// Write the CSV rendering to `path`.
    pub fn write_csv(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_csv())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

/// Schema check of a study artifact: version, required counters, every
/// cell either refused or carrying finite statistics, every point
/// referencing a valid cell, and the counters consistent with the
/// arrays. The `batchrep study` subcommand re-reads and validates the
/// file it wrote, so a malformed artifact fails the CI gate.
pub fn validate_json(j: &Json) -> anyhow::Result<()> {
    anyhow::ensure!(
        j.get("version").and_then(Json::as_i64) == Some(SCHEMA_VERSION),
        "missing or unexpected study schema version"
    );
    for key in ["study", "seed", "axis_points", "unique_cells", "deduped_points", "refused_cells"]
    {
        anyhow::ensure!(j.get(key).is_some(), "missing key '{key}'");
    }
    let cells = j
        .get("cells")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow::anyhow!("missing or non-array 'cells'"))?;
    anyhow::ensure!(!cells.is_empty(), "study artifact has no cells");
    let mut refused = 0i64;
    for (i, c) in cells.iter().enumerate() {
        let backend = c
            .get("backend")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("cell {i} missing 'backend'"))?;
        BackendSel::parse(backend).map_err(|e| anyhow::anyhow!("cell {i}: {e}"))?;
        anyhow::ensure!(c.get("key").and_then(Json::as_str).is_some(), "cell {i} missing 'key'");
        if c.get("refused").is_some() {
            refused += 1;
            continue;
        }
        for stat in ["mean", "variance", "sem"] {
            let v = c
                .get(stat)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("cell {i} missing '{stat}'"))?;
            anyhow::ensure!(v.is_finite(), "cell {i} has non-finite '{stat}' = {v}");
        }
        let samples = c
            .get("samples")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow::anyhow!("cell {i} missing 'samples'"))?;
        anyhow::ensure!(samples >= 0, "cell {i} has negative samples");
    }
    let points = j
        .get("points")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow::anyhow!("missing or non-array 'points'"))?;
    for (i, p) in points.iter().enumerate() {
        let cell = p
            .get("cell")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow::anyhow!("point {i} missing 'cell'"))?;
        anyhow::ensure!(
            cell >= 0 && (cell as usize) < cells.len(),
            "point {i} references cell {cell} of {}",
            cells.len()
        );
        for key in ["n", "b", "policy", "service", "backend"] {
            anyhow::ensure!(p.get(key).is_some(), "point {i} missing '{key}'");
        }
    }
    let count = |key: &str| j.get(key).and_then(Json::as_i64).unwrap_or(-1);
    anyhow::ensure!(
        count("axis_points") == points.len() as i64,
        "axis_points {} != points array length {}",
        count("axis_points"),
        points.len()
    );
    anyhow::ensure!(
        count("unique_cells") == cells.len() as i64,
        "unique_cells {} != cells array length {}",
        count("unique_cells"),
        cells.len()
    );
    anyhow::ensure!(
        count("deduped_points") == points.len() as i64 - cells.len() as i64,
        "deduped_points {} inconsistent with {} points / {} cells",
        count("deduped_points"),
        points.len(),
        cells.len()
    );
    anyhow::ensure!(
        count("refused_cells") == refused,
        "refused_cells {} != {} cells carrying a refusal",
        count("refused_cells"),
        refused
    );
    Ok(())
}

/// Read `path` and [`validate_json`] it.
pub fn validate_file(path: &Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    validate_json(&j)?;
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{BatchService, ServiceSpec};
    use crate::study::{execute, BatchAxis, StudySpec};

    fn smoke_report() -> StudyReport {
        let spec = StudySpec {
            n_workers: vec![8],
            batches: BatchAxis::Explicit(vec![2, 4]),
            services: vec![BatchService::paper(ServiceSpec::shifted_exp(1.0, 0.2))],
            backends: vec![BackendSel::Analytic, BackendSel::MonteCarlo],
            mc_trials: 2_000,
            ..StudySpec::base("report-test")
        };
        let plan = spec.compile().unwrap();
        execute(&plan, 2, &mut |_, _, _, _| {}).unwrap()
    }

    #[test]
    fn artifact_round_trips_and_validates() {
        let report = smoke_report();
        let j = report.to_json();
        validate_json(&j).unwrap();
        let path = std::env::temp_dir().join("batchrep_study_report_test.json");
        report.write(&path).unwrap();
        let parsed = validate_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(parsed.get("version").and_then(Json::as_i64), Some(SCHEMA_VERSION));
        assert_eq!(parsed.get("study").and_then(Json::as_str), Some("report-test"));
        assert_eq!(
            parsed.get("points").and_then(Json::as_array).map(<[Json]>::len),
            Some(report.points.len())
        );
    }

    #[test]
    fn validation_rejects_malformed_documents() {
        assert!(validate_json(&Json::parse("{}").unwrap()).is_err());
        let report = smoke_report();
        let good = report.to_json();
        validate_json(&good).unwrap();
        // Dropping a cell breaks the unique_cells counter.
        if let Json::Object(mut m) = good.clone() {
            if let Some(Json::Array(cells)) = m.get_mut("cells") {
                cells.pop();
            }
            assert!(validate_json(&Json::Object(m)).is_err());
        } else {
            panic!("artifact is an object");
        }
        // A point referencing a missing cell is rejected.
        if let Json::Object(mut m) = good.clone() {
            if let Some(Json::Array(points)) = m.get_mut("points") {
                if let Some(Json::Object(p)) = points.first_mut() {
                    p.insert("cell".into(), Json::Num(1e6));
                }
            }
            assert!(validate_json(&Json::Object(m)).is_err());
        }
        // Wrong version is malformed.
        assert!(validate_json(&Json::parse("{\"version\": 99}").unwrap()).is_err());
    }

    #[test]
    fn csv_has_one_row_per_point() {
        let report = smoke_report();
        let csv = report.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 1 + report.points.len(), "header + one row per point");
        assert!(lines[0].starts_with("n,b,eff_b,policy,service"));
        // Service names contain commas — the CSV must quote them.
        assert!(lines[1].contains("\"sexp:1,0.2/size_scaled\""), "{}", lines[1]);
    }

    #[test]
    fn lookups_surface_refusals() {
        let report = smoke_report();
        // Analytic cells exist for this grid.
        assert!(report.stats_where(&|c| c.backend == BackendSel::Analytic && c.b == 2).is_ok());
        assert!(report.point_where(&|c| c.b == 99).is_none());
        assert!(report.try_stats_where(&|c| c.b == 99).is_none());
        assert!(report.stats_where(&|c| c.b == 99).is_err());
    }
}
