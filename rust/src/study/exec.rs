//! Plan execution: one shared worker pool for every Monte-Carlo and DES
//! cell of a study, serial analytic/live cells on the coordinating
//! thread, streaming [`CellResult`]s as cells complete.
//!
//! ## Determinism
//!
//! Each MC/DES cell is split into the same fixed logical shards its
//! standalone evaluator would use (`des::montecarlo::shard_plan`
//! keyed by the cell's `(trials, scenario.seed)`), and the resulting
//! `(cell, shard)` work items are claimed by pool workers in arbitrary
//! order. Because every shard owns an independent RNG substream and a
//! cell's shard summaries are merged **in shard-index order** once its
//! last shard lands, each cell's [`CompletionStats`] is bit-identical to
//! what `MonteCarloEvaluator`/`DesEvaluator` would produce — for any
//! thread count and any interleaving with other cells. Only the
//! *streaming order* of the progress callback depends on scheduling;
//! the collected [`StudyReport`] does not (live cells excepted: they
//! measure wall clock).
//!
//! ## Resource sharing
//!
//! The pool spans the whole study, so a straggling cell no longer
//! serializes the sweep: workers drain shards of whatever cell still
//! has work. Analytic cells all run on the coordinating thread while
//! the pool works, grouped by cell key, so the entire study shares one
//! thread-local `ct_cache` memo (`analysis::completion_time_stats`).
//! Live cells run **after** the pool has fully drained, so their
//! wall-clock overhead numbers are measured without scheduler
//! contention from the shard workers.

use super::report::{CellOutcome, CellResult, StudyReport};
use super::{BackendSel, ExecutionPlan, PlannedCell};
use crate::coordinator::Backend;
use crate::des::engine::{self, EngineConfig, EngineSummary, Redundancy, Workspace};
use crate::des::montecarlo::{self, McSummary, TrialScratch};
use crate::evaluator::{
    stats_from_des, stats_from_mc, AnalyticEvaluator, CompletionStats, Evaluator, LiveEvaluator,
};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Which simulation family a pooled cell belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Mc,
    Des,
}

/// A completed shard of one pooled cell.
enum ShardOut {
    Mc(McSummary),
    Des(EngineSummary),
}

/// One `(cell, shard)` work item of the shared pool.
struct Item {
    cell: usize,
    acc: usize,
    shard: usize,
    trials: u64,
    rng: crate::util::rng::Rng,
    kind: Kind,
    keep: u64,
}

/// Shard slots of one pooled cell; merged in shard-index order when
/// `remaining` reaches zero.
struct Acc {
    slots: Vec<Option<ShardOut>>,
    remaining: usize,
}

/// Execute a compiled plan on up to `threads` pool workers, invoking
/// `on_cell(cell, result, completed, total)` from the coordinating
/// thread as each cell finishes (in completion order), and return the
/// collected [`StudyReport`] (in plan order — deterministic per seed
/// for any `threads`).
///
/// Backend refusals (e.g. the analytic backend on an out-of-scope
/// scenario, Monte-Carlo on speculative redundancy) are recorded as
/// [`CellOutcome::Refused`] with the backend's own message rather than
/// aborting the study.
pub fn execute(
    plan: &ExecutionPlan,
    threads: usize,
    on_cell: &mut dyn FnMut(&PlannedCell, &CellResult, usize, usize),
) -> anyhow::Result<StudyReport> {
    let _span = crate::obs::span("study.execute");
    crate::obs::bump(crate::obs::Counter::StudyCells, plan.cells.len() as u64);
    crate::obs::bump(crate::obs::Counter::StudyDeduped, plan.deduped_points() as u64);
    if crate::obs::enabled() {
        crate::obs::emit(
            "study",
            "plan",
            &[
                ("cells", plan.cells.len().into()),
                ("axis_points", plan.points.len().into()),
                ("deduped", plan.deduped_points().into()),
                ("threads", threads.into()),
            ],
        );
    }
    let total = plan.cells.len();
    let mut results: Vec<Option<CellResult>> = plan.cells.iter().map(|_| None).collect();
    let mut done = 0usize;

    // Partition: analytic cells run serially on this thread while the
    // pool works; live cells run serially *after* the pool drains, so
    // their wall-clock measurements (the OverheadStats this layer
    // surfaces) are not contaminated by scheduler contention from the
    // saturated shard pool. MC/DES cells are pooled. Monte-Carlo cells
    // outside the sampler's scope are refused at plan time, mirroring
    // the evaluator's check.
    let mut serial: Vec<usize> = Vec::new();
    let mut live_cells: Vec<usize> = Vec::new();
    let mut pool: Vec<(usize, Kind)> = Vec::new();
    for (i, c) in plan.cells.iter().enumerate() {
        match c.backend {
            BackendSel::Analytic => serial.push(i),
            BackendSel::Live => live_cells.push(i),
            BackendSel::Des => pool.push((i, Kind::Des)),
            BackendSel::MonteCarlo => {
                if c.scenario.redundancy == Redundancy::Upfront {
                    pool.push((i, Kind::Mc));
                } else {
                    results[i] = Some(refused(
                        c,
                        format!(
                            "monte-carlo evaluator models upfront replication only; \
                             Scenario::redundancy = {:?} is unsupported (use the des \
                             backend for speculative redundancy)",
                            c.scenario.redundancy
                        ),
                    ));
                }
            }
        }
    }
    for (i, c) in plan.cells.iter().enumerate() {
        if let Some(r) = &results[i] {
            done += 1;
            note_cell(c, r);
            on_cell(c, r, done, total);
        }
    }
    // Group the analytic leg by cell key, so same-service/same-cluster
    // cells are adjacent and all hit the one coordinating-thread
    // ct_cache memo.
    serial.sort_by(|&a, &b| plan.cells[a].key.cmp(&plan.cells[b].key));

    // Flatten pooled cells into (cell, shard) work items over the
    // shared 64-logical-shard plan.
    let mut items: Vec<Item> = Vec::new();
    let mut accs: Vec<Mutex<Acc>> = Vec::new();
    for &(ci, kind) in &pool {
        let c = &plan.cells[ci];
        let shards = montecarlo::shard_plan(c.trials, c.scenario.seed);
        let keep = montecarlo::keep_every(c.trials);
        let acc = accs.len();
        accs.push(Mutex::new(Acc {
            slots: (0..shards.len()).map(|_| None).collect(),
            remaining: shards.len(),
        }));
        for (shard, (trials, rng)) in shards.into_iter().enumerate() {
            items.push(Item { cell: ci, acc, shard, trials, rng, kind, keep });
        }
    }

    let next = AtomicUsize::new(0);
    let workers = threads.max(1).min(items.len());
    let (tx, rx) = std::sync::mpsc::channel::<(usize, CellResult)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let txc = tx.clone();
            let next = &next;
            let items = &items;
            let accs = &accs;
            scope.spawn(move || {
                let mut scratch = TrialScratch::new();
                let mut ws = Workspace::default();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    let it = &items[i];
                    let c = &plan.cells[it.cell];
                    let out = match it.kind {
                        Kind::Mc => ShardOut::Mc(montecarlo::run_shard(
                            &c.scenario,
                            it.trials,
                            it.rng.clone(),
                            it.keep,
                            &mut scratch,
                        )),
                        Kind::Des => {
                            let cfg = EngineConfig {
                                cancellation: plan.spec.des_cancellation,
                                redundancy: c.scenario.redundancy,
                                fail_prob: 0.0,
                                relaunch_timeout_factor: 3.0,
                                ..EngineConfig::default()
                            };
                            ShardOut::Des(engine::simulate_shard(
                                &c.scenario,
                                &cfg,
                                it.trials,
                                it.rng.clone(),
                                it.keep,
                                &mut ws,
                            ))
                        }
                    };
                    let mut acc =
                        accs[it.acc].lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                    acc.slots[it.shard] = Some(out);
                    acc.remaining -= 1;
                    if acc.remaining == 0 {
                        let res = merge_cell(c, &mut acc.slots);
                        // The receiver outlives every sender inside this
                        // scope; a send can only fail on coordinator
                        // panic, which aborts the study anyway.
                        let _ = txc.send((it.cell, res));
                    }
                }
            });
        }
        drop(tx);

        // Analytic cells on the coordinating thread while the pool works.
        for &ci in &serial {
            let c = &plan.cells[ci];
            let res = from_eval(c, AnalyticEvaluator.evaluate(&c.scenario));
            done += 1;
            note_cell(c, &res);
            on_cell(c, &res, done, total);
            results[ci] = Some(res);
        }

        // Drain pooled completions; ends when every worker has dropped
        // its sender.
        for (ci, res) in rx {
            done += 1;
            note_cell(&plan.cells[ci], &res);
            on_cell(&plan.cells[ci], &res, done, total);
            results[ci] = Some(res);
        }
    });

    // Live cells last, with every pool thread joined: their wall-clock
    // numbers (dispatch/channel/aggregation overhead) are measured on
    // an otherwise idle process.
    for &ci in &live_cells {
        let c = &plan.cells[ci];
        let lk = &plan.spec.live;
        let live = LiveEvaluator {
            rounds: c.trials.max(1),
            backend: if lk.pjrt { Backend::Pjrt } else { Backend::Mock },
            time_scale: lk.time_scale,
            n_samples: lk.n_samples,
            dim: lk.dim,
            cancellation: lk.cancellation,
            artifacts_dir: lk.artifacts_dir.clone(),
        };
        let res = from_eval(c, live.evaluate(&c.scenario));
        done += 1;
        note_cell(c, &res);
        on_cell(c, &res, done, total);
        results[ci] = Some(res);
    }

    let mut cells: Vec<CellResult> = Vec::with_capacity(results.len());
    for (ci, r) in results.into_iter().enumerate() {
        match r {
            Some(cell) => cells.push(cell),
            None => anyhow::bail!("planned cell {ci} produced no result"),
        }
    }
    let refused_cells =
        cells.iter().filter(|c| matches!(c.outcome, CellOutcome::Refused(_))).count() as u64;
    Ok(StudyReport {
        name: plan.spec.name.clone(),
        seed: plan.spec.seed,
        quantiles: plan.spec.quantiles,
        cost: plan.spec.cost,
        axis_points: plan.points.len() as u64,
        unique_cells: cells.len() as u64,
        deduped_points: plan.deduped_points() as u64,
        refused_cells,
        points: plan.points.clone(),
        cells,
    })
}

/// Observability hook at every cell-completion site (refusal, serial
/// analytic, pooled drain, live): bump the refusal counter and, with a
/// sink installed, emit one `study/cell` event per finished cell.
fn note_cell(c: &PlannedCell, r: &CellResult) {
    let refused = matches!(r.outcome, CellOutcome::Refused(_));
    if refused {
        crate::obs::bump(crate::obs::Counter::StudyRefused, 1);
    }
    if crate::obs::enabled() {
        crate::obs::emit(
            "study",
            "cell",
            &[
                ("key", r.key.clone().into()),
                ("backend", c.backend.name().into()),
                ("trials", c.trials.into()),
                ("outcome", if refused { "refused" } else { "stats" }.into()),
            ],
        );
    }
}

fn refused(c: &PlannedCell, msg: String) -> CellResult {
    CellResult {
        key: c.key.clone(),
        backend: c.backend,
        trials: c.trials,
        outcome: CellOutcome::Refused(msg),
    }
}

fn from_eval(c: &PlannedCell, r: anyhow::Result<CompletionStats>) -> CellResult {
    CellResult {
        key: c.key.clone(),
        backend: c.backend,
        trials: c.trials,
        outcome: match r {
            Ok(st) => CellOutcome::Stats(st),
            Err(e) => CellOutcome::Refused(format!("{e:#}")),
        },
    }
}

/// Merge a pooled cell's shard summaries through the *same* shard-merge
/// and stats-assembly code the standalone evaluators use
/// (`merge_shard_summaries` + `stats_from_mc`/`stats_from_des`), so the
/// pool reproduces `MonteCarloEvaluator`/`DesEvaluator` by
/// construction, not by parallel maintenance.
fn merge_cell(c: &PlannedCell, slots: &mut [Option<ShardOut>]) -> CellResult {
    let stats = match c.backend {
        BackendSel::MonteCarlo => {
            stats_from_mc(montecarlo::merge_shard_summaries(slots.iter_mut().map(|s| {
                match s.take() {
                    Some(ShardOut::Mc(sh)) => sh,
                    _ => unreachable!("monte-carlo cell holds monte-carlo shards"),
                }
            })))
        }
        BackendSel::Des => {
            stats_from_des(engine::merge_shard_summaries(slots.iter_mut().map(|s| {
                match s.take() {
                    Some(ShardOut::Des(sh)) => sh,
                    _ => unreachable!("des cell holds des shards"),
                }
            })))
        }
        _ => unreachable!("serial cells are never pooled"),
    };
    CellResult {
        key: c.key.clone(),
        backend: c.backend,
        trials: c.trials,
        outcome: CellOutcome::Stats(stats),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{BatchService, ServiceSpec};
    use crate::evaluator::{DesEvaluator, MonteCarloEvaluator};
    use crate::study::{BatchAxis, KTarget, RedundancyAxis, StudySpec};

    fn small_spec() -> StudySpec {
        StudySpec {
            n_workers: vec![12],
            batches: BatchAxis::Explicit(vec![3, 4]),
            services: vec![BatchService::paper(ServiceSpec::shifted_exp(1.0, 0.2))],
            backends: vec![BackendSel::Analytic, BackendSel::MonteCarlo, BackendSel::Des],
            mc_trials: 6_000,
            des_trials: 2_000,
            seed: 11,
            ..StudySpec::base("exec-test")
        }
    }

    #[test]
    fn pooled_cells_match_their_standalone_evaluators_bitwise() {
        // The acceptance bar of the shared pool: interleaving shards of
        // many cells across one pool must not change any cell's result
        // relative to the standalone evaluator at the same
        // (scenario, trials, seed).
        let plan = small_spec().compile().unwrap();
        let report = execute(&plan, 4, &mut |_, _, _, _| {}).unwrap();
        for (i, cell) in plan.cells.iter().enumerate() {
            let got = report.cells[i].stats().expect("no refusals in this grid");
            let want = match cell.backend {
                BackendSel::Analytic => {
                    AnalyticEvaluator.evaluate(&cell.scenario).unwrap()
                }
                BackendSel::MonteCarlo => MonteCarloEvaluator {
                    trials: cell.trials,
                    threads: 3,
                }
                .evaluate(&cell.scenario)
                .unwrap(),
                BackendSel::Des => DesEvaluator {
                    trials: cell.trials,
                    threads: 2,
                    ..DesEvaluator::default()
                }
                .evaluate(&cell.scenario)
                .unwrap(),
                BackendSel::Live => unreachable!(),
            };
            assert_eq!(got.mean.to_bits(), want.mean.to_bits(), "{}", cell.key);
            assert_eq!(got.variance.to_bits(), want.variance.to_bits(), "{}", cell.key);
            assert_eq!(got.sem.to_bits(), want.sem.to_bits(), "{}", cell.key);
            assert_eq!(got.samples, want.samples, "{}", cell.key);
            assert_eq!(got.quantiles, want.quantiles, "{}", cell.key);
            match (&got.cost, &want.cost) {
                (None, None) => {}
                (Some(a), Some(b)) => {
                    assert_eq!(a.busy.to_bits(), b.busy.to_bits(), "{}", cell.key);
                    assert_eq!(a.wasted.to_bits(), b.wasted.to_bits(), "{}", cell.key);
                }
                other => panic!("cost mismatch for {}: {other:?}", cell.key),
            }
        }
    }

    #[test]
    fn report_is_bit_deterministic_for_any_thread_count() {
        // The acceptance property: the collected report (serialized
        // artifact included) is identical for threads ∈ {1, 2, 4, 8}.
        let plan = small_spec().compile().unwrap();
        let baseline = execute(&plan, 1, &mut |_, _, _, _| {}).unwrap().to_json().to_string();
        for threads in [2usize, 4, 8] {
            let run = execute(&plan, threads, &mut |_, _, _, _| {}).unwrap();
            assert_eq!(
                run.to_json().to_string(),
                baseline,
                "report diverged at {threads} threads"
            );
        }
    }

    #[test]
    fn streaming_reports_every_cell_exactly_once() {
        let plan = small_spec().compile().unwrap();
        let mut seen: Vec<String> = Vec::new();
        let mut last = 0usize;
        let report = execute(&plan, 2, &mut |cell, res, done, total| {
            assert_eq!(total, plan.cells.len());
            assert_eq!(done, last + 1, "completion counter is monotone");
            last = done;
            assert_eq!(cell.key, res.key);
            seen.push(res.key.clone());
        })
        .unwrap();
        assert_eq!(seen.len(), plan.cells.len());
        let mut sorted = seen.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), seen.len(), "no cell streamed twice");
        assert_eq!(report.cells.len(), plan.cells.len());
        assert_eq!(report.axis_points as usize, plan.points.len());
    }

    #[test]
    fn refusals_are_recorded_not_fatal() {
        // Monte-Carlo under speculative redundancy and analytic on a
        // heavy-tail spec both refuse; DES serves every cell.
        let spec = StudySpec {
            n_workers: vec![8],
            batches: BatchAxis::Explicit(vec![2]),
            services: vec![BatchService::paper(ServiceSpec::pareto(0.5, 3.5))],
            redundancy: vec![RedundancyAxis::Speculative(1.5)],
            backends: vec![BackendSel::Analytic, BackendSel::MonteCarlo, BackendSel::Des],
            mc_trials: 1_000,
            des_trials: 1_000,
            ..StudySpec::base("refusal-test")
        };
        let plan = spec.compile().unwrap();
        let report = execute(&plan, 2, &mut |_, _, _, _| {}).unwrap();
        assert_eq!(report.refused_cells, 2);
        let refusal_of = |b: BackendSel| {
            report
                .cells
                .iter()
                .find(|c| c.backend == b)
                .and_then(|c| c.refusal())
                .map(str::to_string)
        };
        let mc = refusal_of(BackendSel::MonteCarlo).expect("mc cell refused");
        assert!(mc.contains("Scenario::redundancy"), "{mc}");
        let an = refusal_of(BackendSel::Analytic).expect("analytic cell refused");
        assert!(an.contains("Scenario::redundancy") || an.contains("service"), "{an}");
        assert!(refusal_of(BackendSel::Des).is_none(), "des serves every cell");
    }

    #[test]
    fn k_of_b_and_redundancy_cells_flow_through_the_pool() {
        // A grid reaching the partial-aggregation closed form and the
        // speculative engine path: analytic↔MC agreement on the k cell,
        // and the speculative DES cell is slower but cheaper than
        // upfront (Ablation 3's invariant, now planner-served).
        let spec = StudySpec {
            n_workers: vec![12],
            batches: BatchAxis::Explicit(vec![4]),
            services: vec![BatchService::paper(ServiceSpec::shifted_exp(1.0, 0.2))],
            redundancy: vec![RedundancyAxis::Upfront, RedundancyAxis::Speculative(1.5)],
            k_targets: vec![KTarget::Full, KTarget::Exact(2)],
            backends: vec![BackendSel::Analytic, BackendSel::MonteCarlo, BackendSel::Des],
            mc_trials: 40_000,
            des_trials: 15_000,
            seed: 5,
            ..StudySpec::base("k-spec-test")
        };
        let plan = spec.compile().unwrap();
        let report = execute(&plan, 4, &mut |_, _, _, _| {}).unwrap();
        let stats = |f: &dyn Fn(&crate::study::PointCoords) -> bool| {
            report.stats_where(f).expect("cell present and served").clone()
        };
        let upfront = |c: &crate::study::PointCoords| c.redundancy_idx == 0;
        let an_k =
            stats(&|c| upfront(c) && c.k_of_b == Some(2) && c.backend == BackendSel::Analytic);
        let mc_k =
            stats(&|c| upfront(c) && c.k_of_b == Some(2) && c.backend == BackendSel::MonteCarlo);
        assert!(
            (an_k.mean - mc_k.mean).abs() <= (4.0 * mc_k.sem).max(0.01 * an_k.mean),
            "analytic {} vs mc {}",
            an_k.mean,
            mc_k.mean
        );
        let des_up = stats(&|c| upfront(c) && c.k_of_b.is_none() && c.backend == BackendSel::Des);
        let des_spec = stats(&|c| {
            c.redundancy_idx == 1 && c.k_of_b.is_none() && c.backend == BackendSel::Des
        });
        assert!(des_spec.mean > des_up.mean, "speculative must be slower");
        assert!(
            des_spec.cost.unwrap().busy < des_up.cost.unwrap().busy,
            "speculative must be cheaper"
        );
    }
}
