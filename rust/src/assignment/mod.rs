//! Batch→worker assignment policies (the paper's §II second stage).
//!
//! An [`Assignment`] maps each of `B` batches to the set of workers that
//! will redundantly execute it. The paper's Theorem 1 claims the
//! **balanced assignment of non-overlapping batches** minimizes expected
//! completion time among all policies when service times are
//! stochastically decreasing and convex; the other policies here are the
//! comparison points for that claim (experiment E2) and for the
//! robustness ablations (E8).

use crate::util::rng::Rng;

/// A concrete batch→worker assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Assignment {
    /// Number of workers `N`.
    pub n_workers: usize,
    /// Number of batches `B`.
    pub n_batches: usize,
    /// `workers_of_batch[b]` = workers redundantly executing batch `b`.
    pub workers_of_batch: Vec<Vec<usize>>,
    /// `batch_of_worker[w]` = the batch worker `w` executes (every policy
    /// in the paper gives each worker exactly one batch).
    pub batch_of_worker: Vec<usize>,
}

impl Assignment {
    /// Build the inverse map from `batch_of_worker`.
    fn from_batch_of_worker(n_workers: usize, n_batches: usize, bow: Vec<usize>) -> Self {
        let mut workers_of_batch = vec![Vec::new(); n_batches];
        for (w, &b) in bow.iter().enumerate() {
            workers_of_batch[b].push(w);
        }
        Self { n_workers, n_batches, workers_of_batch, batch_of_worker: bow }
    }

    /// Replication degree of batch `b`.
    pub fn replication(&self, b: usize) -> usize {
        self.workers_of_batch[b].len()
    }

    /// Validate structural invariants:
    /// * every worker is assigned exactly one batch;
    /// * every batch has at least one worker;
    /// * the two maps are mutually consistent.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.batch_of_worker.len() == self.n_workers,
            "batch_of_worker length {} != n_workers {}",
            self.batch_of_worker.len(),
            self.n_workers
        );
        anyhow::ensure!(
            self.workers_of_batch.len() == self.n_batches,
            "workers_of_batch length mismatch"
        );
        let mut seen = vec![false; self.n_workers];
        for (b, ws) in self.workers_of_batch.iter().enumerate() {
            anyhow::ensure!(!ws.is_empty(), "batch {b} has no workers");
            for &w in ws {
                anyhow::ensure!(w < self.n_workers, "worker index {w} out of range");
                anyhow::ensure!(!seen[w], "worker {w} assigned twice");
                seen[w] = true;
                anyhow::ensure!(
                    self.batch_of_worker[w] == b,
                    "inconsistent maps at worker {w}"
                );
            }
        }
        anyhow::ensure!(seen.iter().all(|&s| s), "some worker unassigned");
        Ok(())
    }

    /// True when all replication degrees are equal (balanced). The
    /// degenerate empty assignment (`n_batches == 0`) is vacuously
    /// balanced.
    pub fn is_balanced(&self) -> bool {
        if self.n_batches == 0 {
            return true;
        }
        let g = self.replication(0);
        (0..self.n_batches).all(|b| self.replication(b) == g)
    }
}

/// Assignment policy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// The paper's optimum: batch `b` → workers `{b·g, …, b·g+g−1}` with
    /// `g = N/B`. Requires `B | N`.
    BalancedDisjoint,
    /// Balanced group sizes but the batch→worker map is a uniformly
    /// random balanced grouping. Completion-time–equivalent to
    /// `BalancedDisjoint` under i.i.d. service (sanity check in E2).
    RandomBalanced,
    /// Unbalanced baseline: replication degrees form a maximally skewed
    /// partition — the first batches get extra replicas, the last get
    /// fewer (but ≥ 1). Theorem 1 says this is strictly worse.
    SkewedUnbalanced,
    /// One batch (`B = 1`) replicated everywhere: full diversity.
    FullDiversity,
    /// `B = N`, one worker per batch: full parallelism (no redundancy).
    FullParallelism,
}

impl Policy {
    /// All comparison policies (used by experiment drivers).
    pub fn all() -> &'static [Policy] {
        &[
            Policy::BalancedDisjoint,
            Policy::RandomBalanced,
            Policy::SkewedUnbalanced,
            Policy::FullDiversity,
            Policy::FullParallelism,
        ]
    }

    /// Table/config identifier.
    pub fn name(&self) -> &'static str {
        match self {
            Policy::BalancedDisjoint => "balanced_disjoint",
            Policy::RandomBalanced => "random_balanced",
            Policy::SkewedUnbalanced => "skewed_unbalanced",
            Policy::FullDiversity => "full_diversity",
            Policy::FullParallelism => "full_parallelism",
        }
    }

    /// Parse from config string.
    pub fn parse(s: &str) -> anyhow::Result<Policy> {
        Ok(match s {
            "balanced_disjoint" => Policy::BalancedDisjoint,
            "random_balanced" => Policy::RandomBalanced,
            "skewed_unbalanced" => Policy::SkewedUnbalanced,
            "full_diversity" => Policy::FullDiversity,
            "full_parallelism" => Policy::FullParallelism,
            _ => anyhow::bail!("unknown policy '{s}'"),
        })
    }

    /// Build an assignment of `n_batches` batches onto `n_workers`
    /// workers. For `FullDiversity`/`FullParallelism` the `n_batches`
    /// argument is ignored (they fix `B = 1` / `B = N`).
    pub fn assign(
        &self,
        n_workers: usize,
        n_batches: usize,
        rng: &mut Rng,
    ) -> anyhow::Result<Assignment> {
        anyhow::ensure!(n_workers > 0, "need at least one worker");
        match self {
            Policy::FullDiversity => balanced(n_workers, 1),
            Policy::FullParallelism => balanced(n_workers, n_workers),
            Policy::BalancedDisjoint => balanced(n_workers, n_batches),
            Policy::RandomBalanced => {
                let a = balanced(n_workers, n_batches)?;
                let mut bow = a.batch_of_worker;
                rng.shuffle(&mut bow);
                Ok(Assignment::from_batch_of_worker(n_workers, n_batches, bow))
            }
            Policy::SkewedUnbalanced => skewed(n_workers, n_batches),
        }
    }
}

/// Balanced assignment: requires `n_batches | n_workers`; batch `b` gets
/// workers `[b·g, (b+1)·g)`.
pub fn balanced(n_workers: usize, n_batches: usize) -> anyhow::Result<Assignment> {
    anyhow::ensure!(n_batches >= 1 && n_batches <= n_workers, "need 1 <= B <= N");
    anyhow::ensure!(
        n_workers % n_batches == 0,
        "balanced assignment needs B | N (got N={n_workers}, B={n_batches})"
    );
    let g = n_workers / n_batches;
    let bow: Vec<usize> = (0..n_workers).map(|w| w / g).collect();
    Ok(Assignment::from_batch_of_worker(n_workers, n_batches, bow))
}

/// Maximally skewed (but valid) assignment: batch `i` receives a
/// replication degree that decreases from `2g−1` (capped by remaining
/// workers) down to 1, preserving `Σ degrees = N`.
pub fn skewed(n_workers: usize, n_batches: usize) -> anyhow::Result<Assignment> {
    anyhow::ensure!(n_batches >= 1 && n_batches <= n_workers, "need 1 <= B <= N");
    // Give each batch 1 worker first, then pour the surplus into the
    // earliest batches (2g−1 cap keeps degrees finite but very uneven).
    let g = n_workers / n_batches;
    let cap = (2 * g).max(2) - 1;
    let mut degrees = vec![1usize; n_batches];
    let mut surplus = n_workers - n_batches;
    let mut i = 0;
    while surplus > 0 {
        let room = cap.saturating_sub(degrees[i]);
        let add = room.min(surplus);
        degrees[i] += add;
        surplus -= add;
        i += 1;
        if i == n_batches {
            // Cap too small to absorb the surplus; relax it.
            i = 0;
            for d in &mut degrees {
                if surplus == 0 {
                    break;
                }
                *d += 1;
                surplus -= 1;
            }
        }
    }
    let mut bow = Vec::with_capacity(n_workers);
    for (b, &d) in degrees.iter().enumerate() {
        bow.extend(std::iter::repeat(b).take(d));
    }
    Ok(Assignment::from_batch_of_worker(n_workers, n_batches, bow))
}

/// Divisors of `n` in increasing order — the feasible set `F_B` of batch
/// counts for balanced assignment.
pub fn feasible_batch_counts(n: usize) -> Vec<usize> {
    let mut out: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn balanced_structure() {
        let a = balanced(12, 4).unwrap();
        a.validate().unwrap();
        assert!(a.is_balanced());
        assert_eq!(a.replication(0), 3);
        assert_eq!(a.workers_of_batch[1], vec![3, 4, 5]);
        assert_eq!(a.batch_of_worker[7], 2);
    }

    #[test]
    fn balanced_rejects_non_divisor() {
        assert!(balanced(10, 3).is_err());
        assert!(balanced(10, 0).is_err());
        assert!(balanced(4, 5).is_err());
    }

    #[test]
    fn full_diversity_and_parallelism() {
        let mut rng = Rng::new(1);
        let d = Policy::FullDiversity.assign(8, 99, &mut rng).unwrap();
        assert_eq!(d.n_batches, 1);
        assert_eq!(d.replication(0), 8);
        let p = Policy::FullParallelism.assign(8, 99, &mut rng).unwrap();
        assert_eq!(p.n_batches, 8);
        assert!(p.is_balanced());
        assert_eq!(p.replication(3), 1);
    }

    #[test]
    fn random_balanced_is_balanced_and_valid() {
        let mut rng = Rng::new(2);
        let a = Policy::RandomBalanced.assign(12, 3, &mut rng).unwrap();
        a.validate().unwrap();
        assert!(a.is_balanced());
        assert_eq!(a.replication(0), 4);
    }

    #[test]
    fn skewed_is_valid_and_unbalanced() {
        let a = skewed(12, 4).unwrap();
        a.validate().unwrap();
        assert!(!a.is_balanced());
        // degrees: 5,5,1,1 (cap 2g−1 = 5)
        assert_eq!(a.replication(0), 5);
        assert_eq!(a.replication(3), 1);
        let total: usize = (0..4).map(|b| a.replication(b)).sum();
        assert_eq!(total, 12);
    }

    #[test]
    fn empty_assignment_is_balanced_without_panicking() {
        // Regression: `is_balanced` used to index `workers_of_batch[0]`
        // unconditionally and panicked on the empty assignment.
        let a = Assignment {
            n_workers: 0,
            n_batches: 0,
            workers_of_batch: Vec::new(),
            batch_of_worker: Vec::new(),
        };
        assert!(a.is_balanced());
    }

    #[test]
    fn feasible_counts() {
        assert_eq!(feasible_batch_counts(24), vec![1, 2, 3, 4, 6, 8, 12, 24]);
        assert_eq!(feasible_batch_counts(1), vec![1]);
        assert_eq!(feasible_batch_counts(7), vec![1, 7]);
    }

    #[test]
    fn prop_all_policies_valid() {
        testkit::check("policies-valid", 200, |g| {
            let n = g.usize_in(1, 48);
            let divisors = feasible_batch_counts(n);
            let b = *g.pick(&divisors);
            let policy = *g.pick(Policy::all());
            let mut rng = g.rng();
            let a = policy.assign(n, b, &mut rng).unwrap();
            a.validate().unwrap();
            // Total replication always equals N (every worker works).
            let total: usize = (0..a.n_batches).map(|i| a.replication(i)).sum();
            assert_eq!(total, n);
        });
    }

    #[test]
    fn prop_skewed_total_is_n_even_for_non_divisors() {
        testkit::check("skewed-nondivisor", 200, |g| {
            let n = g.usize_in(2, 64);
            let b = g.usize_in(1, n);
            let a = skewed(n, b).unwrap();
            a.validate().unwrap();
        });
    }
}
