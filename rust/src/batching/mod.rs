//! Stage one of the paper's two-stage data distribution: samples →
//! batches.
//!
//! Data is normalized into `U` equal *units* (the paper takes `U = N`, so
//! a batch of the dataset's `1/B` fraction holds `s = N/B` units). A
//! [`DataLayout`] describes which units each batch holds; batches are
//! either **disjoint** (a partition — the paper's optimum) or
//! **overlapping** (cyclic shifted windows — the paper's comparison
//! class, where every worker's subset partially overlaps its
//! neighbours'). The layout also maps units to concrete sample-index
//! ranges of a real dataset for the live coordinator.

/// Which units (of `n_units` total) each batch holds.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DataLayout {
    /// Total number of normalized data units `U`.
    pub n_units: usize,
    /// `units_of_batch[b]` = sorted unit ids in batch `b`.
    pub units_of_batch: Vec<Vec<usize>>,
    /// True when built by [`overlapping`].
    pub is_overlapping: bool,
}

impl DataLayout {
    /// Number of batches.
    pub fn n_batches(&self) -> usize {
        self.units_of_batch.len()
    }

    /// Batch size in units (all batches are equal-sized by construction).
    pub fn batch_units(&self) -> usize {
        self.units_of_batch[0].len()
    }

    /// Validate: equal batch sizes, unit ids in range, full coverage,
    /// and (for disjoint layouts) exact partition.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.units_of_batch.is_empty(), "no batches");
        let s = self.batch_units();
        anyhow::ensure!(s > 0, "empty batches");
        let mut count = vec![0usize; self.n_units];
        for (b, us) in self.units_of_batch.iter().enumerate() {
            anyhow::ensure!(us.len() == s, "batch {b} size {} != {s}", us.len());
            for &u in us {
                anyhow::ensure!(u < self.n_units, "unit {u} out of range");
                count[u] += 1;
            }
        }
        anyhow::ensure!(count.iter().all(|&c| c > 0), "coverage hole");
        if !self.is_overlapping {
            anyhow::ensure!(
                count.iter().all(|&c| c == 1),
                "disjoint layout has a duplicated unit"
            );
        }
        Ok(())
    }

    /// Map a batch to a concrete half-open sample range set for a dataset
    /// of `n_samples` rows: unit `u` covers
    /// `[u·n_samples/U, (u+1)·n_samples/U)`. Returns coalesced
    /// `(start, end)` ranges.
    pub fn sample_ranges(&self, b: usize, n_samples: usize) -> Vec<(usize, usize)> {
        let u_total = self.n_units;
        let mut ranges: Vec<(usize, usize)> = self.units_of_batch[b]
            .iter()
            .map(|&u| (u * n_samples / u_total, (u + 1) * n_samples / u_total))
            .collect();
        ranges.sort_unstable();
        // Coalesce adjacent ranges.
        let mut out: Vec<(usize, usize)> = Vec::with_capacity(ranges.len());
        for (s, e) in ranges {
            match out.last_mut() {
                Some(last) if last.1 == s => last.1 = e,
                _ => out.push((s, e)),
            }
        }
        out
    }
}

/// Disjoint partition into `n_batches` equal batches (`n_batches`
/// must divide `n_units`). Batch `b` = units `[b·s, (b+1)·s)`.
pub fn disjoint(n_units: usize, n_batches: usize) -> anyhow::Result<DataLayout> {
    anyhow::ensure!(n_batches >= 1 && n_batches <= n_units, "need 1 <= B <= U");
    anyhow::ensure!(
        n_units % n_batches == 0,
        "disjoint layout needs B | U (got U={n_units}, B={n_batches})"
    );
    let s = n_units / n_batches;
    let units_of_batch =
        (0..n_batches).map(|b| (b * s..(b + 1) * s).collect()).collect();
    Ok(DataLayout { n_units, units_of_batch, is_overlapping: false })
}

/// Overlapping cyclic layout: `n_batches` windows of `batch_units` units,
/// window `b` starting at `b·(U/n_batches)` and wrapping modulo `U`.
/// With `n_batches = U` and `batch_units = s` this is the classic
/// shift-by-one overlapped placement; total storage equals the disjoint
/// layout with the same per-worker batch size.
pub fn overlapping(
    n_units: usize,
    n_batches: usize,
    batch_units: usize,
) -> anyhow::Result<DataLayout> {
    anyhow::ensure!(n_batches >= 1, "need B >= 1");
    anyhow::ensure!(
        batch_units >= 1 && batch_units <= n_units,
        "batch size must be in [1, U]"
    );
    anyhow::ensure!(
        n_units % n_batches == 0,
        "cyclic layout needs B | U (got U={n_units}, B={n_batches})"
    );
    let stride = n_units / n_batches;
    // Coverage requires each stride gap be covered by the window length.
    anyhow::ensure!(
        batch_units >= stride,
        "windows of {batch_units} units with stride {stride} leave holes"
    );
    let units_of_batch = (0..n_batches)
        .map(|b| {
            let mut us: Vec<usize> =
                (0..batch_units).map(|k| (b * stride + k) % n_units).collect();
            us.sort_unstable();
            us
        })
        .collect();
    Ok(DataLayout { n_units, units_of_batch, is_overlapping: true })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn disjoint_partition() {
        let l = disjoint(24, 4).unwrap();
        l.validate().unwrap();
        assert_eq!(l.batch_units(), 6);
        assert_eq!(l.units_of_batch[1], (6..12).collect::<Vec<_>>());
    }

    #[test]
    fn disjoint_rejects_bad_b() {
        assert!(disjoint(10, 3).is_err());
        assert!(disjoint(4, 8).is_err());
    }

    #[test]
    fn overlapping_wraps_and_covers() {
        // 8 units, 8 windows of 3: batch 7 wraps to {7, 0, 1}.
        let l = overlapping(8, 8, 3).unwrap();
        l.validate().unwrap();
        assert_eq!(l.units_of_batch[7], vec![0, 1, 7]);
    }

    #[test]
    fn overlapping_detects_holes() {
        // stride 4, window 3 → units 3 mod 4 uncovered.
        assert!(overlapping(8, 2, 3).is_err());
    }

    #[test]
    fn sample_ranges_coalesce() {
        let l = disjoint(4, 2).unwrap();
        // batch 0 = units {0,1} → one coalesced range covering half.
        assert_eq!(l.sample_ranges(0, 100), vec![(0, 50)]);
        assert_eq!(l.sample_ranges(1, 100), vec![(50, 100)]);
        let o = overlapping(4, 4, 2).unwrap();
        // batch 3 = units {0, 3} → two ranges.
        assert_eq!(o.sample_ranges(3, 100), vec![(0, 25), (75, 100)]);
    }

    #[test]
    fn prop_disjoint_layout_valid() {
        testkit::check("disjoint-valid", 200, |g| {
            let u = g.usize_in(1, 64);
            let divisors: Vec<usize> = (1..=u).filter(|b| u % b == 0).collect();
            let b = *g.pick(&divisors);
            let l = disjoint(u, b).unwrap();
            l.validate().unwrap();
            // Ranges tile [0, n_samples).
            let n_samples = g.usize_in(u, 10_000);
            let mut all: Vec<(usize, usize)> =
                (0..b).flat_map(|i| l.sample_ranges(i, n_samples)).collect();
            all.sort_unstable();
            let mut pos = 0;
            for (s, e) in all {
                assert_eq!(s, pos);
                pos = e;
            }
            assert_eq!(pos, n_samples);
        });
    }

    #[test]
    fn prop_overlapping_coverage() {
        testkit::check("overlap-coverage", 200, |g| {
            let u = g.usize_in(2, 48);
            let divisors: Vec<usize> = (1..=u).filter(|b| u % b == 0).collect();
            let b = *g.pick(&divisors);
            let stride = u / b;
            let size = g.usize_in(stride.min(u), u);
            let l = overlapping(u, b, size).unwrap();
            l.validate().unwrap();
        });
    }
}
