//! Closed-form analysis of the job completion time (paper §III).
//!
//! Under the size-dependent service model, a batch of `s = N/B` units on
//! one worker serves in `s·τ`. For `τ ~ SExp(µ, ∆)` that is
//! `SExp(µ/s, s∆)`; the earliest of the `g = N/B` replicas of a batch
//! finishes in `s∆ + Exp(g·µ/s) = s∆ + Exp(µ)` (the replication degree
//! exactly cancels the size scaling when the assignment is balanced —
//! the elegance at the heart of the paper). The job completion time is
//! then `T = s∆ + max{E₁, …, E_B}` with `E_i` i.i.d. `Exp(µ)`:
//!
//! * `E[T]  = N∆/B + H_B/µ`          (paper Eq. 4; Exp case has ∆ = 0)
//! * `Var[T] = H⁽²⁾_B/µ²`
//!
//! This module also computes the exact mean/variance of **unbalanced**
//! balanced-size assignments by inclusion–exclusion over the maximum of
//! independent non-identical exponentials, which lets E2 verify
//! Theorem 1 analytically rather than only by simulation; and
//! completion-time statistics for **heterogeneous-speed** clusters
//! ([`hetero_completion_bounds`]): exact per-worker-rate order
//! statistics for Exponential service, a provable two-sided bound for
//! Shifted-Exponential — the closed-form legs of the conformance
//! matrix's `worker_speeds` cells.
//!
//! The balanced closed form is **memoized** per `(N, B, spec)` in a
//! thread-local cache (see [`ct_cache_counters`]), and the harmonic
//! sums it is built from are table lookups, so dense `∆µ` sweeps
//! ([`bstar_sweep`], `evaluator::paper_sweep`) never recompute a point.

use crate::assignment::{feasible_batch_counts, Assignment};
use crate::dist::ServiceSpec;
use crate::util::harmonic::{harmonic, harmonic2};
use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;

/// Mean/variance of a completion time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CtStats {
    /// Expected completion time.
    pub mean: f64,
    /// Variance of the completion time.
    pub var: f64,
}

impl CtStats {
    /// Standard deviation.
    pub fn stddev(&self) -> f64 {
        self.var.sqrt()
    }
}

/// Extract `(mu, delta)` for the closed forms; only Exp and SExp have
/// them (∆ = 0 for Exp). Thin alias over [`ServiceSpec::exp_family`].
fn exp_family(spec: &ServiceSpec) -> Option<(f64, f64)> {
    spec.exp_family()
}

/// Memo key of one closed-form evaluation: `(N, B, spec)` with the
/// exp-family parameters keyed by their exact bit patterns. The
/// homogeneous balanced entry point uses `kind = 0` (shape hashes 0);
/// [`hetero_completion_bounds`] stores its inclusion–exclusion base
/// under `kind = 1` with **two independent** 64-bit fingerprints of
/// the per-worker speeds and the batch-of-worker map (FNV-1a and a
/// SplitMix64 fold), so dense heterogeneous sweeps recompute nothing
/// and a silent same-key collision would need both 64-bit hashes to
/// collide at once (~2⁻¹²⁸ per pair).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct CtKey {
    n: u64,
    /// Data units `U` — distinct from `n` in the heterogeneous entry
    /// point, where the per-worker rates scale with `s = U/B` (the
    /// homogeneous closed form is defined at the paper normalization
    /// `U = N`).
    units: u64,
    b: u64,
    mu_bits: u64,
    delta_bits: u64,
    kind: u8,
    shape_hash: u64,
    shape_hash2: u64,
}

impl CtKey {
    /// Key of the homogeneous balanced closed form (`U = N`).
    fn homogeneous(n: u64, b: u64, mu: f64, delta: f64) -> Self {
        Self {
            n,
            units: n,
            b,
            mu_bits: mu.to_bits(),
            delta_bits: delta.to_bits(),
            kind: 0,
            shape_hash: 0,
            shape_hash2: 0,
        }
    }
}

/// Two independent fingerprints (FNV-1a and a SplitMix64 fold) over the
/// worker-speed bit patterns and the batch-of-worker map — the part of
/// a heterogeneous scenario the `(N, B, spec)` key cannot see.
fn hetero_shape_hashes(speeds: &[f64], batch_of_worker: &[usize]) -> (u64, u64) {
    let mut fnv: u64 = 0xcbf2_9ce4_8422_2325;
    let mut smx: u64 = 0x9E37_79B9_7F4A_7C15;
    let mut eat = |v: u64| {
        for byte in v.to_le_bytes() {
            fnv = (fnv ^ byte as u64).wrapping_mul(0x1000_0000_01b3);
        }
        let mut s = smx ^ v.wrapping_mul(0xA24B_AED4_963E_E407);
        smx = crate::util::rng::splitmix64(&mut s);
    };
    eat(speeds.len() as u64);
    for &s in speeds {
        eat(s.to_bits());
    }
    for &b in batch_of_worker {
        eat(b as u64);
    }
    (fnv, smx)
}

thread_local! {
    /// Per-thread memo of [`completion_time_stats`] results. Thread-local
    /// rather than global so sweeps never contend on a lock and tests
    /// observe exact hit/miss counts.
    static CT_CACHE: RefCell<BTreeMap<CtKey, CtStats>> = RefCell::new(BTreeMap::new());
    static CT_HITS: Cell<u64> = Cell::new(0);
    static CT_MISSES: Cell<u64> = Cell::new(0);
}

/// Entry cap of the per-thread memo; reaching it clears the map (sweeps
/// touch a few thousand keys at most, so this is a leak guard, not a
/// working-set limit).
const CT_CACHE_CAP: usize = 1 << 16;

/// `(hits, misses)` of the calling thread's closed-form memo since
/// thread start — the observability hook the sweep-caching tests (and
/// perf investigations) read.
pub fn ct_cache_counters() -> (u64, u64) {
    (CT_HITS.with(|h| h.get()), CT_MISSES.with(|m| m.get()))
}

/// Closed-form completion-time statistics of System1 with `n` workers,
/// `b` batches, balanced disjoint assignment, and per-unit service
/// `spec` (must be Exp or SExp; `b` must divide `n`).
///
/// Results are memoized per `(n, b, spec)` in a thread-local cache, so
/// dense sweeps (`bstar_sweep`, `paper_sweep`, repeated `optimum_b`
/// scans) evaluate each distinct point once per thread.
pub fn completion_time_stats(n: u64, b: u64, spec: &ServiceSpec) -> anyhow::Result<CtStats> {
    anyhow::ensure!(n >= 1 && b >= 1 && b <= n, "need 1 <= B <= N");
    anyhow::ensure!(n % b == 0, "closed form needs B | N (N={n}, B={b})");
    let (mu, delta) = exp_family(spec)
        .ok_or_else(|| anyhow::anyhow!("closed form only for exp/sexp, got {}", spec.name()))?;
    let key = CtKey::homogeneous(n, b, mu, delta);
    if let Some(st) = ct_cache_get(&key) {
        return Ok(st);
    }
    CT_MISSES.with(|m| m.set(m.get() + 1));
    let s = (n / b) as f64; // batch size in units == replication degree
    let st = CtStats {
        mean: s * delta + harmonic(b) / mu,
        var: harmonic2(b) / (mu * mu),
    };
    ct_cache_put(key, st);
    Ok(st)
}

/// Memo lookup (bumps the hit counters on success).
fn ct_cache_get(key: &CtKey) -> Option<CtStats> {
    let hit = CT_CACHE.with(|c| c.borrow().get(key).copied());
    if hit.is_some() {
        CT_HITS.with(|h| h.set(h.get() + 1));
        crate::obs::bump(crate::obs::Counter::CtHit, 1);
    }
    hit
}

/// Memo insert with the leak-guard cap. Every insert is a miss that was
/// just computed, so this is also where the process-wide miss counter
/// and (when a sink is installed) the `analysis/cache_miss` event live —
/// exactly mirroring the thread-local `CT_MISSES` semantics.
fn ct_cache_put(key: CtKey, st: CtStats) {
    crate::obs::bump(crate::obs::Counter::CtMiss, 1);
    if crate::obs::enabled() {
        crate::obs::emit("analysis", "cache_miss", &[("n", key.n.into()), ("b", key.b.into())]);
    }
    CT_CACHE.with(|c| {
        let mut map = c.borrow_mut();
        if map.len() >= CT_CACHE_CAP {
            map.clear();
        }
        map.insert(key, st);
    });
}

/// One point of the diversity–parallelism spectrum.
#[derive(Debug, Clone, Copy)]
pub struct SpectrumPoint {
    /// Number of batches `B`.
    pub b: u64,
    /// Replication degree `g = N/B`.
    pub g: u64,
    /// Closed-form statistics at this `B`.
    pub stats: CtStats,
}

/// Evaluate the closed form at every feasible `B` (divisors of `N`).
pub fn spectrum(n: u64, spec: &ServiceSpec) -> anyhow::Result<Vec<SpectrumPoint>> {
    feasible_batch_counts(n as usize)
        .into_iter()
        .map(|b| {
            let b = b as u64;
            Ok(SpectrumPoint { b, g: n / b, stats: completion_time_stats(n, b, spec)? })
        })
        .collect()
}

/// Theorem 3 optimizer: the `B ∈ F_B` minimizing expected completion
/// time. For Exp this is always 1 (Theorem 2). Errors (like
/// [`spectrum`]) on service specs without a closed form.
pub fn optimum_b(n: u64, spec: &ServiceSpec) -> anyhow::Result<u64> {
    Ok(spectrum(n, spec)?
        .into_iter()
        .min_by(|a, b| a.stats.mean.total_cmp(&b.stats.mean))
        .map(|p| p.b)
        .unwrap_or(1))
}

/// The `B` minimizing the *variance* (Theorems 2 & 4 prove this is 1 for
/// both distributions; computed rather than assumed so tests can check).
pub fn optimum_b_variance(n: u64, spec: &ServiceSpec) -> anyhow::Result<u64> {
    Ok(spectrum(n, spec)?
        .into_iter()
        .min_by(|a, b| a.stats.var.total_cmp(&b.stats.var))
        .map(|p| p.b)
        .unwrap_or(1))
}

/// Partial-aggregation completion (extension, motivated by the paper's
/// gradient-coding citation [7]): the master generates an *approximate*
/// result from the earliest `k ≤ B` batches instead of all `B` (e.g.,
/// SGD with a fraction of the gradient terms). The completion time is
/// then the k-th order statistic of `B` i.i.d. `s∆ + Exp(µ)` batch-min
/// times:
/// `E[T_(k)] = s∆ + (H_B − H_{B−k})/µ`,
/// `Var[T_(k)] = (H⁽²⁾_B − H⁽²⁾_{B−k})/µ²`.
/// `k = B` recovers [`completion_time_stats`].
pub fn partial_completion_stats(
    n: u64,
    b: u64,
    k: u64,
    spec: &ServiceSpec,
) -> anyhow::Result<CtStats> {
    anyhow::ensure!(n >= 1 && b >= 1 && b <= n && n % b == 0, "need B | N");
    anyhow::ensure!(k >= 1 && k <= b, "need 1 <= k <= B");
    let (mu, delta) = exp_family(spec)
        .ok_or_else(|| anyhow::anyhow!("closed form only for exp/sexp"))?;
    let s = (n / b) as f64;
    Ok(CtStats {
        mean: s * delta + (harmonic(b) - harmonic(b - k)) / mu,
        var: (harmonic2(b) - harmonic2(b - k)) / (mu * mu),
    })
}

/// Monte-Carlo sampler for the k-of-B completion (validates
/// [`partial_completion_stats`] and covers distributions with no closed
/// form). Balanced disjoint assignment.
pub fn sample_partial_completion(
    n: u64,
    b: u64,
    k: u64,
    service: &crate::dist::BatchService,
    rng: &mut crate::util::rng::Rng,
) -> f64 {
    let g = (n / b) as usize;
    let s = n / b;
    let mut mins: Vec<f64> = (0..b)
        .map(|_| {
            crate::util::stats::fold_min_total((0..g).map(|_| service.sample_batch(s, rng)))
        })
        .collect();
    mins.sort_unstable_by(f64::total_cmp);
    mins[(k - 1) as usize]
}

/// m-of-g **verified** completion — the result-integrity closed form:
/// replica voting waits for the `m`-th replica of every batch instead
/// of the first, and the job completes at the `k`-th finished batch
/// (`k = B` = full completion; `m = 1` recovers
/// [`partial_completion_stats`] / [`completion_time_stats`]).
///
/// Under the size-scaled model with the paper normalization `U = N`,
/// one replica of a batch takes `s∆ + Exp(λ)` with `s = N/B` and
/// `λ = µ/s`. Write `u = e^{−λt}` for `t` measured past the `s∆`
/// shift. The per-replica CDF is `1 − u`; the per-batch (m-of-g) CDF
/// is the binomial tail `Σ_{j≥m} C(g,j) (1−u)^j u^{g−j}` — a
/// degree-`g` polynomial in `u` — and the job (k-of-B) CDF is the
/// binomial tail of *that* polynomial, of degree `g·B = N`. Writing
/// the composed CDF as `1 + Σ_{i≥1} cᵢ uⁱ`, tail integration gives
/// exactly
/// `E[T] − s∆ = (1/λ) Σᵢ (−cᵢ)/i` and
/// `E[(T − s∆)²] = (2/λ²) Σᵢ (−cᵢ)/i²`.
///
/// The expansion is exact but its binomial coefficients alternate in
/// sign, so the form is restricted to `N ≤ 32` where every
/// intermediate coefficient is exactly representable in f64 —
/// simulation backends cover larger clusters.
pub fn verified_completion_stats(
    n: u64,
    b: u64,
    m: u64,
    k: u64,
    spec: &ServiceSpec,
) -> anyhow::Result<CtStats> {
    anyhow::ensure!(n >= 1 && b >= 1 && b <= n && n % b == 0, "need B | N");
    anyhow::ensure!(k >= 1 && k <= b, "need 1 <= k <= B");
    let g = n / b;
    anyhow::ensure!(
        m >= 1 && m <= g,
        "verified completion needs 1 <= m <= g = N/B (N={n}, B={b}, m={m})"
    );
    anyhow::ensure!(
        n <= 32,
        "verified closed form limited to N <= 32 (exact polynomial coefficients); got N={n}"
    );
    let (mu, delta) = exp_family(spec)
        .ok_or_else(|| anyhow::anyhow!("closed form only for exp/sexp, got {}", spec.name()))?;
    let s = g as f64;
    let lambda = mu / s;
    // Per-replica CDF as a polynomial in u: 1 − u.
    let replica = vec![1.0, -1.0];
    let batch = binomial_tail_poly(&replica, g as usize, m as usize);
    let total = binomial_tail_poly(&batch, b as usize, k as usize);
    // total[0] = 1 (the CDF reaches 1 as t → ∞, u → 0); integrate the
    // survival function term by term: ∫₀¹ u^{i−1} du = 1/i and
    // ∫₀¹ u^{i−1}(−ln u) du = 1/i².
    let mut mean_acc = 0.0;
    let mut m2_acc = 0.0;
    for (i, &c) in total.iter().enumerate().skip(1) {
        mean_acc -= c / i as f64;
        m2_acc -= c / (i as f64 * i as f64);
    }
    let mean_past_shift = mean_acc / lambda;
    let m2 = 2.0 * m2_acc / (lambda * lambda);
    Ok(CtStats {
        mean: s * delta + mean_past_shift,
        var: m2 - mean_past_shift * mean_past_shift,
    })
}

/// Expected redundancy bill of one m-of-g verified job (full
/// completion, balanced disjoint, `U = N`), as `(busy, wasted)`
/// worker-seconds. Every replica of a batch runs until the batch
/// verifies at its m-th order statistic `T₍m₎`: the `m` winners
/// contribute their own finish times `T₍1₎ … T₍m₎`, the `g − m` losers
/// are cancelled at `T₍m₎` (they are the `wasted` share), with
/// `E[T₍i₎] = s∆ + (H_g − H_{g−i})·s/µ`.
pub fn verified_cost_stats(
    n: u64,
    b: u64,
    m: u64,
    spec: &ServiceSpec,
) -> anyhow::Result<(f64, f64)> {
    anyhow::ensure!(n >= 1 && b >= 1 && b <= n && n % b == 0, "need B | N");
    let g = n / b;
    anyhow::ensure!(
        m >= 1 && m <= g,
        "verified cost needs 1 <= m <= g = N/B (N={n}, B={b}, m={m})"
    );
    let (mu, delta) = exp_family(spec)
        .ok_or_else(|| anyhow::anyhow!("closed form only for exp/sexp, got {}", spec.name()))?;
    let s = g as f64;
    let e_order = |i: u64| s * delta + (harmonic(g) - harmonic(g - i)) * s / mu;
    let e_m = e_order(m);
    let mut busy_per_batch = (g - m) as f64 * e_m;
    for i in 1..=m {
        busy_per_batch += e_order(i);
    }
    let wasted_per_batch = (g - m) as f64 * e_m;
    Ok((b as f64 * busy_per_batch, b as f64 * wasted_per_batch))
}

/// `p(u) · q(u)` for coefficient vectors indexed by power of `u`.
fn poly_mul(p: &[f64], q: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; p.len() + q.len() - 1];
    for (i, &pi) in p.iter().enumerate() {
        if pi == 0.0 {
            continue;
        }
        for (j, &qj) in q.iter().enumerate() {
            out[i + j] += pi * qj;
        }
    }
    out
}

/// The binomial tail `Σ_{j=m}^{g} C(g,j) A(u)^j (1 − A(u))^{g−j}` as a
/// polynomial in `u` — the CDF of the m-th order statistic of `g`
/// i.i.d. variables whose CDF is the polynomial `A(u)`.
fn binomial_tail_poly(a: &[f64], g: usize, m: usize) -> Vec<f64> {
    let mut one_minus = a.iter().map(|&c| -c).collect::<Vec<f64>>();
    one_minus[0] += 1.0;
    // Powers A^j and (1−A)^j for j = 0..=g, then the weighted sum.
    let mut pow_a: Vec<Vec<f64>> = vec![vec![1.0]];
    let mut pow_c: Vec<Vec<f64>> = vec![vec![1.0]];
    for j in 1..=g {
        pow_a.push(poly_mul(&pow_a[j - 1], a));
        pow_c.push(poly_mul(&pow_c[j - 1], &one_minus));
    }
    let mut out: Vec<f64> = Vec::new();
    for j in m..=g {
        let term = poly_mul(&pow_a[j], &pow_c[g - j]);
        if out.len() < term.len() {
            out.resize(term.len(), 0.0);
        }
        let w = binom(g, j);
        for (i, &c) in term.iter().enumerate() {
            out[i] += w * c;
        }
    }
    out
}

/// `C(n, k)` by the multiplicative recurrence (exact in f64 for the
/// `n ≤ 32` range the verified closed form is restricted to).
fn binom(n: usize, k: usize) -> f64 {
    let k = k.min(n - k);
    let mut c = 1.0;
    for i in 0..k {
        c = c * (n - i) as f64 / (i + 1) as f64;
    }
    c
}

/// Mean and variance of `max{X₁, …, X_k}` for independent `X_i ~
/// Exp(rates[i])`, by inclusion–exclusion:
/// `E[max] = Σ_{∅≠S} (−1)^{|S|+1} / λ_S`,
/// `E[max²] = Σ_{∅≠S} (−1)^{|S|+1} · 2/λ_S²`, with `λ_S = Σ_{i∈S} λ_i`.
/// Exponential in `k`; fine for `k ≤ 20` (experiment sizes).
pub fn max_of_exponentials_stats(rates: &[f64]) -> CtStats {
    let k = rates.len();
    assert!(k >= 1 && k <= 25, "inclusion-exclusion limited to k <= 25");
    let mut mean = 0.0;
    let mut m2 = 0.0;
    for mask in 1u32..(1u32 << k) {
        let mut lam = 0.0;
        for (i, &r) in rates.iter().enumerate() {
            if mask & (1 << i) != 0 {
                lam += r;
            }
        }
        let sign = if mask.count_ones() % 2 == 1 { 1.0 } else { -1.0 };
        mean += sign / lam;
        m2 += sign * 2.0 / (lam * lam);
    }
    CtStats { mean, var: m2 - mean * mean }
}

/// Exact completion-time statistics for an arbitrary (possibly
/// unbalanced) assignment of equal-size disjoint batches under Exp/SExp
/// per-unit service. Batch `i` with replication degree `gᵢ` has its
/// earliest replica finish at `s∆ + Exp(gᵢ·µ/s)`; the completion time is
/// the max over batches.
pub fn assignment_stats(
    assignment: &Assignment,
    spec: &ServiceSpec,
    n_units: u64,
) -> anyhow::Result<CtStats> {
    let (mu, delta) = exp_family(spec)
        .ok_or_else(|| anyhow::anyhow!("closed form only for exp/sexp"))?;
    let b = assignment.n_batches as u64;
    anyhow::ensure!(n_units % b == 0, "need B | U for equal-size batches");
    let s = (n_units / b) as f64;
    let rates: Vec<f64> = (0..assignment.n_batches)
        .map(|i| assignment.replication(i) as f64 * mu / s)
        .collect();
    let base = max_of_exponentials_stats(&rates);
    Ok(CtStats { mean: s * delta + base.mean, var: base.var })
}

/// Completion-time bounds for a **heterogeneous-speed** cluster: exact
/// for Exponential service, a provable two-sided bound for
/// Shifted-Exponential.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CtBounds {
    /// Stochastic lower bound on `(E[T], Var-model)`.
    pub lower: CtStats,
    /// Stochastic upper bound.
    pub upper: CtStats,
    /// `true` when `lower == upper` (Exponential service, or a uniform
    /// speed factor) — the bound collapses to the exact value.
    pub exact: bool,
}

impl CtBounds {
    /// Midpoint of the mean interval (the exact mean when `exact`).
    pub fn mid_mean(&self) -> f64 {
        0.5 * (self.lower.mean + self.upper.mean)
    }

    /// Half-width of the mean interval (0 when `exact`).
    pub fn half_width(&self) -> f64 {
        0.5 * (self.upper.mean - self.lower.mean)
    }
}

/// Closed-form completion-time bounds under **heterogeneous worker
/// speeds** (the `Scenario::worker_speeds` field): worker `w` with
/// speed factor `c_w ≥ 0` serves its batch of `s` units in
/// `c_w·(s∆ + Exp(µ/s)) = c_w·s∆ + Exp(µ/(s·c_w))`, so batch `i`'s
/// earliest replica has exponential part `Exp(Λᵢ)` with per-worker
/// rates `λ_w = µ/(s·c_w)` summed over its replicas:
///
/// * **Exponential (∆ = 0): exact.** `T = max_i Exp(Λᵢ)`, evaluated by
///   inclusion–exclusion over the per-batch rates
///   ([`max_of_exponentials_stats`]) — the per-worker-rate order
///   statistics, with no homogeneity assumption.
/// * **Shifted-Exponential: two-sided bound.** `c_w·s∆ + Exp(λ_w)`
///   is stochastically sandwiched by shifting every worker to the
///   cluster-wide `c_min`/`c_max`:
///   `s∆·c_min + max_i Exp(Λᵢ)  ≤st  T  ≤st  s∆·c_max + max_i Exp(Λᵢ)`,
///   so the mean lies in an interval of width `s∆·(c_max − c_min)`; the
///   exponential part still uses the exact per-worker rates. Both
///   bounds carry the inclusion–exclusion variance of the exponential
///   part (the shift contributes no variance to either bound).
///
/// Requires Exp/SExp per-unit service, equal-size disjoint batches
/// (`B | U`), and `B ≤ 20` (inclusion–exclusion). Works for unbalanced
/// replication degrees. The inclusion–exclusion base is memoized in the
/// same thread-local cache as [`completion_time_stats`], keyed by
/// `(N, B, spec, shape_hash(speeds, assignment))`, so sweeps over a
/// fixed cluster shape evaluate each point once per thread.
pub fn hetero_completion_bounds(
    assignment: &Assignment,
    spec: &ServiceSpec,
    n_units: u64,
    speeds: &[f64],
) -> anyhow::Result<CtBounds> {
    let (mu, delta) = exp_family(spec).ok_or_else(|| {
        anyhow::anyhow!(
            "heterogeneous closed forms cover exp/sexp service only, got {}",
            spec.name()
        )
    })?;
    let n = assignment.n_workers;
    let b = assignment.n_batches as u64;
    anyhow::ensure!(
        speeds.len() == n,
        "worker_speeds has {} entries for {n} workers",
        speeds.len()
    );
    anyhow::ensure!(speeds.iter().all(|&c| c > 0.0), "worker speeds must be positive");
    anyhow::ensure!(n_units % b == 0, "need B | U for equal-size batches");
    anyhow::ensure!(
        b <= 20,
        "heterogeneous inclusion–exclusion limited to B <= 20 (got {b})"
    );
    let s = (n_units / b) as f64;
    let (c_min, c_max) = speeds
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &c| (lo.min(c), hi.max(c)));

    let (shape_hash, shape_hash2) = hetero_shape_hashes(speeds, &assignment.batch_of_worker);
    let key = CtKey {
        n: n as u64,
        units: n_units,
        b,
        mu_bits: mu.to_bits(),
        delta_bits: delta.to_bits(),
        kind: 1,
        shape_hash,
        shape_hash2,
    };
    let base = match ct_cache_get(&key) {
        Some(st) => st,
        None => {
            CT_MISSES.with(|m| m.set(m.get() + 1));
            let rates: Vec<f64> = assignment
                .workers_of_batch
                .iter()
                .map(|ws| ws.iter().map(|&w| mu / (s * speeds[w])).sum())
                .collect();
            let st = max_of_exponentials_stats(&rates);
            ct_cache_put(key, st);
            st
        }
    };

    let lower = CtStats { mean: s * delta * c_min + base.mean, var: base.var };
    let upper = CtStats { mean: s * delta * c_max + base.mean, var: base.var };
    Ok(CtBounds { exact: lower.mean == upper.mean, lower, upper })
}

/// Closed-form CDF of the completion time for balanced disjoint
/// replication under Exp/SExp service:
/// `P(T ≤ t) = (1 − e^{−µ(t − s∆)})^B` for `t ≥ s∆` (0 below).
pub fn completion_time_cdf(n: u64, b: u64, spec: &ServiceSpec, t: f64) -> anyhow::Result<f64> {
    anyhow::ensure!(n >= 1 && b >= 1 && b <= n && n % b == 0, "need B | N");
    let (mu, delta) = exp_family(spec)
        .ok_or_else(|| anyhow::anyhow!("closed form only for exp/sexp"))?;
    let shift = (n / b) as f64 * delta;
    if t <= shift {
        return Ok(0.0);
    }
    Ok((1.0 - (-mu * (t - shift)).exp()).powi(b as i32))
}

/// Closed-form quantile (inverse CDF): the paper's performance-guarantee
/// number ("the job finishes within t with probability q"):
/// `t_q = s∆ − ln(1 − q^{1/B})/µ`.
pub fn completion_time_quantile(
    n: u64,
    b: u64,
    spec: &ServiceSpec,
    q: f64,
) -> anyhow::Result<f64> {
    anyhow::ensure!((0.0..1.0).contains(&q), "q must be in [0, 1)");
    anyhow::ensure!(n >= 1 && b >= 1 && b <= n && n % b == 0, "need B | N");
    let (mu, delta) = exp_family(spec)
        .ok_or_else(|| anyhow::anyhow!("closed form only for exp/sexp"))?;
    let shift = (n / b) as f64 * delta;
    Ok(shift - (1.0 - q.powf(1.0 / b as f64)).ln() / mu)
}

/// Expected *cost* (busy worker-seconds) of one job under upfront
/// replication with cancellation: every replica of a batch runs until
/// the batch's earliest replica finishes, so
/// `E[cost] = B · g · E[min] = N·(N∆/B + 1/µ)`.
/// The redundancy bill the diversity end of the spectrum pays.
pub fn expected_cost(n: u64, b: u64, spec: &ServiceSpec) -> anyhow::Result<f64> {
    anyhow::ensure!(n >= 1 && b >= 1 && b <= n && n % b == 0, "need B | N");
    let (mu, delta) = exp_family(spec)
        .ok_or_else(|| anyhow::anyhow!("closed form only for exp/sexp"))?;
    let s = (n / b) as f64;
    Ok(n as f64 * (s * delta + 1.0 / mu))
}

/// The crossover table behind Fig. 2 / Theorem 3: for each `∆µ` product,
/// the optimal `B*` and whether it is interior (neither 1 nor N).
#[derive(Debug, Clone, Copy)]
pub struct CrossoverPoint {
    /// ∆·µ (the paper's "randomness" knob; large = less random).
    pub delta_mu: f64,
    /// Optimal batch count.
    pub b_star: u64,
    /// Expected completion time at `B*`.
    pub mean_at_star: f64,
}

/// Sweep `∆µ` and record `B*(∆µ)` for fixed `n` and `µ`.
pub fn bstar_sweep(n: u64, mu: f64, delta_mus: &[f64]) -> anyhow::Result<Vec<CrossoverPoint>> {
    delta_mus
        .iter()
        .map(|&dm| {
            let spec = ServiceSpec::shifted_exp(mu, dm / mu);
            let b_star = optimum_b(n, &spec)?;
            let mean = completion_time_stats(n, b_star, &spec)?.mean;
            Ok(CrossoverPoint { delta_mu: dm, b_star, mean_at_star: mean })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::{balanced, skewed};
    use crate::testkit;

    #[test]
    fn eq4_shape() {
        // E[T] = N∆/B + H_B/µ.
        let spec = ServiceSpec::shifted_exp(2.0, 0.3);
        let st = completion_time_stats(24, 4, &spec).unwrap();
        let expect = 6.0 * 0.3 + harmonic(4) / 2.0;
        assert!((st.mean - expect).abs() < 1e-12);
        assert!((st.var - harmonic2(4) / 4.0).abs() < 1e-12);
    }

    #[test]
    fn exp_case_is_delta_zero() {
        let e = completion_time_stats(24, 6, &ServiceSpec::exp(1.5)).unwrap();
        assert!((e.mean - harmonic(6) / 1.5).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(completion_time_stats(10, 3, &ServiceSpec::exp(1.0)).is_err());
        assert!(completion_time_stats(10, 2, &ServiceSpec::pareto(1.0, 2.0)).is_err());
        assert!(completion_time_stats(4, 8, &ServiceSpec::exp(1.0)).is_err());
    }

    #[test]
    fn theorem2_exp_full_diversity_optimal() {
        // Both mean and variance minimized at B = 1 for Exponential.
        for n in [4u64, 12, 24, 60] {
            let spec = ServiceSpec::exp(1.0);
            assert_eq!(optimum_b(n, &spec).unwrap(), 1, "n={n}");
            assert_eq!(optimum_b_variance(n, &spec).unwrap(), 1, "n={n}");
        }
    }

    #[test]
    fn theorem4_sexp_variance_full_diversity() {
        for delta in [0.01, 0.1, 1.0, 10.0] {
            let spec = ServiceSpec::shifted_exp(1.0, delta);
            assert_eq!(optimum_b_variance(24, &spec).unwrap(), 1, "delta={delta}");
        }
    }

    #[test]
    fn theorem3_interior_optimum_moves_with_delta_mu() {
        let n = 24;
        // Very random (tiny ∆µ): diversity wins.
        assert_eq!(optimum_b(n, &ServiceSpec::shifted_exp(1.0, 0.001)).unwrap(), 1);
        // Very deterministic (huge ∆µ): parallelism wins.
        assert_eq!(optimum_b(n, &ServiceSpec::shifted_exp(1.0, 50.0)).unwrap(), 24);
        // Moderate ∆µ: interior optimum.
        let b_mid = optimum_b(n, &ServiceSpec::shifted_exp(1.0, 0.2)).unwrap();
        assert!(b_mid > 1 && b_mid < 24, "b_mid={b_mid}");
        // Monotone: B* nondecreasing in ∆µ.
        let sweep = bstar_sweep(n, 1.0, &[0.001, 0.01, 0.1, 0.3, 1.0, 3.0, 10.0, 50.0]).unwrap();
        for w in sweep.windows(2) {
            assert!(w[1].b_star >= w[0].b_star, "{:?}", sweep);
        }
    }

    #[test]
    fn bstar_sweep_hits_memo_cache_on_dense_grids() {
        // Acceptance gate: a ≥ 50-point ∆µ sweep must evaluate each
        // distinct closed form once — repeats come from the memo.
        // Counters are thread-local and libtest runs each test on its
        // own thread, so the arithmetic here is exact.
        let n = 48u64;
        let grid: Vec<f64> = (0..60).map(|i| 0.013 + i as f64 * 0.0471).collect();
        let (h0, m0) = ct_cache_counters();
        let first = bstar_sweep(n, 1.0, &grid).unwrap();
        let (h1, m1) = ct_cache_counters();
        let points = grid.len() as u64 * feasible_batch_counts(n as usize).len() as u64;
        assert_eq!(m1 - m0, points, "each (B, ∆µ) closed form computed exactly once");
        // Within one pass, re-reading the optimum point must hit.
        assert!(h1 - h0 >= grid.len() as u64, "B* re-lookups should hit the memo");
        let second = bstar_sweep(n, 1.0, &grid).unwrap();
        let (h2, m2) = ct_cache_counters();
        assert_eq!(m2, m1, "second sweep must not recompute any closed form");
        assert_eq!(h2 - h1, points + grid.len() as u64);
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.b_star, b.b_star);
            assert_eq!(a.mean_at_star.to_bits(), b.mean_at_star.to_bits());
        }
    }

    #[test]
    fn memoized_stats_match_fresh_computation() {
        // The cached value must be the value: compare a repeated call
        // against the formula recomputed by hand.
        let spec = ServiceSpec::shifted_exp(1.7, 0.23);
        for _ in 0..3 {
            let st = completion_time_stats(36, 6, &spec).unwrap();
            let expect_mean = 6.0 * 0.23 + harmonic(6) / 1.7;
            let expect_var = harmonic2(6) / (1.7 * 1.7);
            assert_eq!(st.mean.to_bits(), expect_mean.to_bits());
            assert_eq!(st.var.to_bits(), expect_var.to_bits());
        }
    }

    #[test]
    fn max_of_iid_exponentials_matches_harmonics() {
        // max of k iid Exp(µ): mean H_k/µ, var H2_k/µ².
        for k in [1usize, 2, 5, 10] {
            let rates = vec![2.0; k];
            let st = max_of_exponentials_stats(&rates);
            assert!((st.mean - harmonic(k as u64) / 2.0).abs() < 1e-9, "k={k}");
            assert!((st.var - harmonic2(k as u64) / 4.0).abs() < 1e-9, "k={k}");
        }
    }

    #[test]
    fn theorem1_balanced_beats_skewed_analytically() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        for (n, b) in [(12usize, 4usize), (24, 6), (8, 2)] {
            let bal = assignment_stats(&balanced(n, b).unwrap(), &spec, n as u64).unwrap();
            let skw = assignment_stats(&skewed(n, b).unwrap(), &spec, n as u64).unwrap();
            assert!(
                bal.mean < skw.mean,
                "n={n} B={b}: balanced {} !< skewed {}",
                bal.mean,
                skw.mean
            );
        }
    }

    #[test]
    fn hetero_exponential_is_exact_per_worker_rate_order_statistics() {
        // ∆ = 0: the bound collapses and must match a Monte-Carlo run of
        // the same heterogeneous scenario within sampling error.
        let spec = ServiceSpec::exp(1.3);
        let n = 12usize;
        let speeds: Vec<f64> = (0..n).map(|w| 0.6 + 0.12 * w as f64).collect();
        let a = balanced(n, 3).unwrap();
        let bounds = hetero_completion_bounds(&a, &spec, n as u64, &speeds).unwrap();
        assert!(bounds.exact);
        assert_eq!(bounds.lower.mean.to_bits(), bounds.upper.mean.to_bits());
        let scn = crate::des::Scenario::paper_balanced(
            n,
            3,
            crate::dist::BatchService::paper(spec.clone()),
        )
        .unwrap()
        .with_speeds(speeds)
        .unwrap();
        let mc = crate::des::montecarlo::run_trials(&scn, 150_000, 41);
        assert!(
            (mc.mean() - bounds.mid_mean()).abs() < 4.0 * mc.ci95().max(1e-3),
            "mc {} vs exact {}",
            mc.mean(),
            bounds.mid_mean()
        );
        let rel_var = (mc.variance() - bounds.lower.var).abs() / bounds.lower.var;
        assert!(rel_var < 0.06, "var mc {} vs exact {}", mc.variance(), bounds.lower.var);
    }

    #[test]
    fn hetero_uniform_speeds_reduce_to_scaled_homogeneous_closed_form() {
        // A uniform factor c is the homogeneous system with spec
        // (µ/c, c∆): the bound is exact and matches the scaled closed
        // form; c = 1 recovers completion_time_stats itself.
        let spec = ServiceSpec::shifted_exp(1.0, 0.3);
        let a = balanced(12, 4).unwrap();
        for c in [1.0f64, 1.7] {
            let bounds =
                hetero_completion_bounds(&a, &spec, 12, &vec![c; 12]).unwrap();
            assert!(bounds.exact, "c={c}");
            let scaled = ServiceSpec::shifted_exp(1.0 / c, c * 0.3);
            let direct = completion_time_stats(12, 4, &scaled).unwrap();
            assert!(
                (bounds.mid_mean() - direct.mean).abs() < 1e-9,
                "c={c}: {} vs {}",
                bounds.mid_mean(),
                direct.mean
            );
            assert!((bounds.lower.var - direct.var).abs() < 1e-9, "c={c}");
        }
    }

    #[test]
    fn hetero_sexp_bounds_contain_montecarlo_mean() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.4);
        let n = 8usize;
        let speeds: Vec<f64> = (0..n).map(|w| if w % 2 == 0 { 0.7 } else { 1.8 }).collect();
        let a = balanced(n, 2).unwrap();
        let bounds = hetero_completion_bounds(&a, &spec, n as u64, &speeds).unwrap();
        assert!(!bounds.exact);
        assert!(bounds.lower.mean < bounds.upper.mean);
        // Interval width is exactly s∆(c_max − c_min).
        let s = (n / 2) as f64;
        assert!((2.0 * bounds.half_width() - s * 0.4 * (1.8 - 0.7)).abs() < 1e-12);
        let scn = crate::des::Scenario::paper_balanced(
            n,
            2,
            crate::dist::BatchService::paper(spec.clone()),
        )
        .unwrap()
        .with_speeds(speeds)
        .unwrap();
        let mc = crate::des::montecarlo::run_trials(&scn, 150_000, 43);
        let slack = 4.0 * mc.ci95().max(1e-3);
        assert!(
            mc.mean() >= bounds.lower.mean - slack && mc.mean() <= bounds.upper.mean + slack,
            "mc {} outside [{}, {}]",
            mc.mean(),
            bounds.lower.mean,
            bounds.upper.mean
        );
    }

    #[test]
    fn hetero_bounds_work_for_unbalanced_assignments() {
        // The per-worker-rate construction never assumed balance: a
        // skewed assignment's bound must still contain the MC mean.
        let spec = ServiceSpec::exp(1.0);
        let n = 12usize;
        let speeds: Vec<f64> = (0..n).map(|w| 0.5 + 0.1 * w as f64).collect();
        let a = skewed(n, 3).unwrap();
        let bounds = hetero_completion_bounds(&a, &spec, n as u64, &speeds).unwrap();
        let layout = crate::batching::disjoint(n, 3).unwrap();
        let scn = crate::des::Scenario::new(
            layout,
            a,
            crate::dist::BatchService::paper(spec),
        )
        .unwrap()
        .with_speeds(speeds)
        .unwrap();
        let mc = crate::des::montecarlo::run_trials(&scn, 120_000, 47);
        assert!(
            (mc.mean() - bounds.mid_mean()).abs() < 4.0 * mc.ci95().max(1e-3),
            "mc {} vs exact {}",
            mc.mean(),
            bounds.mid_mean()
        );
    }

    #[test]
    fn hetero_bounds_are_memoized_per_shape() {
        let spec = ServiceSpec::shifted_exp(1.2, 0.2);
        let a = balanced(16, 4).unwrap();
        let speeds: Vec<f64> = (0..16).map(|w| 1.0 + 0.05 * w as f64).collect();
        let first = hetero_completion_bounds(&a, &spec, 16, &speeds).unwrap();
        let (h0, m0) = ct_cache_counters();
        let again = hetero_completion_bounds(&a, &spec, 16, &speeds).unwrap();
        let (h1, m1) = ct_cache_counters();
        assert_eq!(m1, m0, "repeat evaluation must not recompute the IE base");
        assert_eq!(h1, h0 + 1);
        assert_eq!(first, again);
        // A different speed vector is a different key.
        let mut other = speeds.clone();
        other[0] *= 2.0;
        let _ = hetero_completion_bounds(&a, &spec, 16, &other).unwrap();
        let (_, m2) = ct_cache_counters();
        assert_eq!(m2, m1 + 1);
    }

    #[test]
    fn hetero_bounds_reject_bad_inputs() {
        let a = balanced(8, 2).unwrap();
        let ok = vec![1.0; 8];
        assert!(hetero_completion_bounds(&a, &ServiceSpec::pareto(1.0, 2.5), 8, &ok).is_err());
        assert!(hetero_completion_bounds(&a, &ServiceSpec::exp(1.0), 8, &ok[..7]).is_err());
        let mut neg = ok.clone();
        neg[3] = 0.0;
        assert!(hetero_completion_bounds(&a, &ServiceSpec::exp(1.0), 8, &neg).is_err());
        let wide = balanced(24, 24).unwrap();
        assert!(
            hetero_completion_bounds(&wide, &ServiceSpec::exp(1.0), 24, &vec![1.0; 24])
                .is_err(),
            "B > 20 exceeds the inclusion–exclusion budget"
        );
    }

    #[test]
    fn assignment_stats_matches_closed_form_when_balanced() {
        let spec = ServiceSpec::shifted_exp(1.5, 0.4);
        let a = balanced(24, 6).unwrap();
        let via_ie = assignment_stats(&a, &spec, 24).unwrap();
        let direct = completion_time_stats(24, 6, &spec).unwrap();
        assert!((via_ie.mean - direct.mean).abs() < 1e-9);
        assert!((via_ie.var - direct.var).abs() < 1e-9);
    }

    #[test]
    fn partial_completion_reduces_to_full_at_k_equals_b() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        for (n, b) in [(24u64, 6u64), (12, 4)] {
            let full = completion_time_stats(n, b, &spec).unwrap();
            let part = partial_completion_stats(n, b, b, &spec).unwrap();
            assert!((full.mean - part.mean).abs() < 1e-12);
            assert!((full.var - part.var).abs() < 1e-12);
        }
    }

    #[test]
    fn partial_completion_monotone_in_k() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let mut prev = 0.0;
        for k in 1..=6 {
            let st = partial_completion_stats(24, 6, k, &spec).unwrap();
            assert!(st.mean > prev);
            prev = st.mean;
        }
        assert!(partial_completion_stats(24, 6, 0, &spec).is_err());
        assert!(partial_completion_stats(24, 6, 7, &spec).is_err());
    }

    #[test]
    fn partial_completion_matches_montecarlo() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.3);
        let service = crate::dist::BatchService::paper(spec.clone());
        let mut rng = crate::util::rng::Rng::new(23);
        for k in [1u64, 3, 4] {
            let theory = partial_completion_stats(24, 4, k.min(4), &spec).unwrap();
            let n_trials = 100_000;
            let mean: f64 = (0..n_trials)
                .map(|_| sample_partial_completion(24, 4, k.min(4), &service, &mut rng))
                .sum::<f64>()
                / n_trials as f64;
            assert!(
                (mean - theory.mean).abs() < 0.02 * theory.mean.max(1.0),
                "k={k}: mc {mean} vs theory {}",
                theory.mean
            );
        }
    }

    #[test]
    fn verified_stats_m1_pins_to_the_unverified_forms() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.05);
        for (n, b) in [(24u64, 4u64), (12, 3), (16, 16), (8, 1)] {
            let v = verified_completion_stats(n, b, 1, b, &spec).unwrap();
            let full = completion_time_stats(n, b, &spec).unwrap();
            assert!((v.mean - full.mean).abs() < 1e-9, "N={n} B={b}");
            assert!((v.var - full.var).abs() < 1e-9, "N={n} B={b}");
        }
        for (n, b, k) in [(24u64, 4u64, 2u64), (12, 6, 5), (32, 8, 3)] {
            let v = verified_completion_stats(n, b, 1, k, &spec).unwrap();
            let part = partial_completion_stats(n, b, k, &spec).unwrap();
            assert!((v.mean - part.mean).abs() < 1e-9, "N={n} B={b} k={k}");
            assert!((v.var - part.var).abs() < 1e-9, "N={n} B={b} k={k}");
        }
        // Degenerate single replica of a single batch is a plain
        // shifted exponential: mean s∆ + 1/λ, var 1/λ².
        let v = verified_completion_stats(1, 1, 1, 1, &spec).unwrap();
        assert!((v.mean - (0.05 + 1.0)).abs() < 1e-12);
        assert!((v.var - 1.0).abs() < 1e-12);
    }

    #[test]
    fn verified_stats_refuse_out_of_range_shapes() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.05);
        // m beyond the replication degree g = N/B.
        assert!(verified_completion_stats(24, 24, 2, 24, &spec).is_err());
        assert!(verified_completion_stats(24, 4, 7, 4, &spec).is_err());
        assert!(verified_completion_stats(24, 4, 0, 4, &spec).is_err());
        assert!(verified_completion_stats(24, 4, 2, 0, &spec).is_err());
        assert!(verified_completion_stats(24, 4, 2, 5, &spec).is_err());
        // Exactness guard: the polynomial form stops at N = 32.
        assert!(verified_completion_stats(64, 8, 2, 8, &spec).is_err());
        assert!(verified_completion_stats(32, 8, 2, 8, &spec).is_ok());
    }

    #[test]
    fn verified_stats_match_montecarlo() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.05);
        let mut rng = crate::util::rng::Rng::new(97);
        for (n, b, m, k) in [(24u64, 4u64, 2u64, 4u64), (24, 4, 3, 4), (12, 3, 2, 2)] {
            let g = n / b;
            let s = g as f64;
            let lambda = 1.0 / s;
            let theory = verified_completion_stats(n, b, m, k, &spec).unwrap();
            let n_trials = 60_000;
            let mut acc = 0.0;
            for _ in 0..n_trials {
                let mut batch_times: Vec<f64> = (0..b)
                    .map(|_| {
                        let mut xs: Vec<f64> = (0..g)
                            .map(|_| -rng.f64_open0().ln() / lambda)
                            .collect();
                        xs.sort_by(f64::total_cmp);
                        s * 0.05 + xs[m as usize - 1]
                    })
                    .collect();
                batch_times.sort_by(f64::total_cmp);
                acc += batch_times[k as usize - 1];
            }
            let mc = acc / n_trials as f64;
            assert!(
                (mc - theory.mean).abs() < 0.03 * theory.mean.max(1.0),
                "N={n} B={b} m={m} k={k}: mc {mc} vs theory {}",
                theory.mean
            );
        }
    }

    #[test]
    fn verified_cost_m1_is_the_cloned_redundancy_bill() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        // m = 1: every replica runs until the batch's first finisher,
        // so busy = B · g · E[T₍1₎] with E[T₍1₎] = s∆ + s/(gµ).
        let (n, b) = (12u64, 3u64);
        let g = n / b;
        let s = g as f64;
        let e_min = s * 0.2 + s / (g as f64 * 1.0);
        let (busy, wasted) = verified_cost_stats(n, b, 1, &spec).unwrap();
        assert!((busy - b as f64 * g as f64 * e_min).abs() < 1e-9);
        assert!((wasted - b as f64 * (g - 1) as f64 * e_min).abs() < 1e-9);
        // m = g: nothing is cancelled, wasted is exactly zero.
        let (_, wasted_all) = verified_cost_stats(n, b, g, &spec).unwrap();
        assert_eq!(wasted_all, 0.0);
        assert!(verified_cost_stats(n, b, g + 1, &spec).is_err());
    }

    #[test]
    fn cdf_properties() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.3);
        let (n, b) = (12u64, 3u64);
        let shift = 4.0 * 0.3;
        // Zero below the shift, monotone, → 1.
        assert_eq!(completion_time_cdf(n, b, &spec, shift - 0.01).unwrap(), 0.0);
        let mut prev = 0.0;
        for i in 1..100 {
            let t = shift + i as f64 * 0.2;
            let c = completion_time_cdf(n, b, &spec, t).unwrap();
            assert!((0.0..=1.0).contains(&c) && c >= prev);
            prev = c;
        }
        assert!(prev > 0.999);
        // Median from quantile inverts the CDF.
        let med = completion_time_quantile(n, b, &spec, 0.5).unwrap();
        let c = completion_time_cdf(n, b, &spec, med).unwrap();
        assert!((c - 0.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_matches_montecarlo() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let scn = crate::des::Scenario::paper_balanced(
            12,
            4,
            crate::dist::BatchService::paper(spec.clone()),
        )
        .unwrap();
        let mut mc = crate::des::montecarlo::run_trials(&scn, 200_000, 31);
        for q in [0.5, 0.9, 0.99] {
            let theory = completion_time_quantile(12, 4, &spec, q).unwrap();
            let emp = mc.samples.quantile(q).unwrap();
            let rel = (theory - emp).abs() / theory;
            assert!(rel < 0.03, "q={q}: theory {theory} vs mc {emp}");
        }
    }

    #[test]
    fn expected_cost_matches_engine() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let scn = crate::des::Scenario::paper_balanced(
            12,
            3,
            crate::dist::BatchService::paper(spec.clone()),
        )
        .unwrap();
        let sum = crate::des::engine::simulate_many(
            &scn,
            &crate::des::engine::EngineConfig::default(),
            100_000,
            17,
        );
        let theory = expected_cost(12, 3, &spec).unwrap();
        let rel = (sum.busy.mean() - theory).abs() / theory;
        assert!(rel < 0.02, "engine busy {} vs theory {theory}", sum.busy.mean());
    }

    #[test]
    fn cost_increases_with_diversity() {
        // Full diversity costs the most; full parallelism the least.
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let costs: Vec<f64> = crate::assignment::feasible_batch_counts(24)
            .into_iter()
            .map(|b| expected_cost(24, b as u64, &spec).unwrap())
            .collect();
        for w in costs.windows(2) {
            assert!(w[1] < w[0], "{costs:?}");
        }
    }

    #[test]
    fn prop_balanced_optimality_over_random_degree_splits() {
        // Theorem 1, property form: any valid degree vector (same batch
        // size, degrees summing to N) has E[T] ≥ balanced E[T].
        testkit::check("thm1-degrees", 150, |g| {
            let choices = [(4usize, 2usize), (8, 4), (12, 3), (12, 4), (16, 4)];
            let (n, b) = *g.pick(&choices);
            let spec = ServiceSpec::shifted_exp(1.0, g.f64_in(0.0, 2.0));
            // Random degree vector: start balanced, move replicas around.
            let gdeg = n / b;
            let mut degrees = vec![gdeg; b];
            for _ in 0..g.usize_in(0, 2 * b) {
                let from = g.usize_in(0, b - 1);
                let to = g.usize_in(0, b - 1);
                if degrees[from] > 1 {
                    degrees[from] -= 1;
                    degrees[to] += 1;
                }
            }
            let mut bow = Vec::new();
            for (i, &d) in degrees.iter().enumerate() {
                bow.extend(std::iter::repeat(i).take(d));
            }
            let mut workers_of_batch = vec![Vec::new(); b];
            for (w, &bb) in bow.iter().enumerate() {
                workers_of_batch[bb].push(w);
            }
            let a = Assignment {
                n_workers: n,
                n_batches: b,
                workers_of_batch,
                batch_of_worker: bow,
            };
            a.validate().unwrap();
            let st = assignment_stats(&a, &spec, n as u64).unwrap();
            let bal = completion_time_stats(n as u64, b as u64, &spec).unwrap();
            assert!(
                st.mean >= bal.mean - 1e-9,
                "degrees {degrees:?}: {} < balanced {}",
                st.mean,
                bal.mean
            );
        });
    }
}
