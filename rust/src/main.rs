//! `batchrep` — CLI launcher for the System1 reproduction.
//!
//! Subcommands:
//!   analyze     closed-form diversity–parallelism spectrum (Theorems 2–4)
//!   evaluate    run one scenario through any Evaluator backend(s) and
//!               cross-check them (analytic | montecarlo | des | live | all)
//!               — planned and executed as a one-point study
//!   study       compile a declarative multi-scenario spec (preset or
//!               spec.json) into a deduplicated plan, run it on the
//!               shared pool, stream per-cell progress, and write a
//!               schema-validated STUDY artifact (+ optional CSV)
//!   control     closed-loop adaptive redundancy: online censored-MLE
//!               estimation + re-planning against a hidden, optionally
//!               drifting true spec (preset or spec.json), regret vs
//!               the oracle plan → schema-validated CONTROL artifact;
//!               --live drives the real thread-backed coordinator
//!               (optionally under a --fault plan)
//!   chaos       replay a declarative fault plan (preset or spec.json)
//!               through the fault-aware event engine: crashes,
//!               respawns, relaunches, degradations, MTTR and
//!               rounds-to-recover → schema-validated CHAOS artifact
//!   integrity   sweep vote size m × corruption probability (preset or
//!               spec.json) through the verified event engine:
//!               detection rate, false positives, quarantine latency
//!               and the m-of-g completion overhead → schema-validated
//!               INTEGRITY artifact
//!   simulate    Monte-Carlo + event-engine simulation of one scenario
//!   experiment  regenerate paper figures/tables (fig2|policies|spectrum|
//!               ablations|extensions|control|live|all)
//!   train       run the live distributed-SGD System1 (PJRT backend)
//!   mapsum      run one live distributed map-sum evaluation
//!   conformance sweep generated scenarios through every backend pair
//!               (z-bound tolerances, deterministic replay seeds;
//!               --long for the soak sweep)
//!   bench-mc    Monte-Carlo throughput harness → BENCH_mc.json
//!   bench-des   event-engine throughput harness → BENCH_des.json
//!   lint        determinism-invariant static analysis over rust/src
//!               (rules D1–D6) — the gate ci.sh runs after clippy
//!   obs         summarize + schema-validate a structured event log
//!               (the `--events <path>` JSONL that evaluate/study/
//!               control/chaos/integrity write): per-span time
//!               breakdown, event counts, relaunch histogram
//!
//! Global options: `--config <file.toml>` plus per-key overrides
//! (`--n-workers 24`, `--service sexp:1.0,0.2`, `--seed 7`, ...). The
//! single `--seed` value flows into every evaluator through the
//! scenario, so all tables are bit-reproducible. See README.

use batchrep::analysis;
use batchrep::config::cli::Args;
use batchrep::config::toml::TomlValue;
use batchrep::config::SystemConfig;
use batchrep::coordinator::{Backend, Coordinator};
use batchrep::des::engine::Redundancy;
use batchrep::evaluator::{
    cross_check_stats, AnalyticEvaluator, DesEvaluator, Evaluator, MonteCarloEvaluator,
};
use batchrep::experiments::{self, ExpContext};
use batchrep::study::{BackendSel, BatchAxis, KTarget, LiveKnobs, RedundancyAxis, StudySpec};
use batchrep::util::table::{fmt_f, Table};

const USAGE: &str = "\
batchrep — data replication for straggler mitigation (Behrouzi-Far & Soljanin, 2019)

USAGE:
  batchrep analyze    [--n 24] [--service sexp:1.0,0.2]
  batchrep evaluate   [--backend analytic|montecarlo|des|live|all] [--cross-check]
                      [--config f] [--n-workers 24] [--n-batches 4] [--policy p]
                      [--service spec] [--trials 100000] [--seed 42] [--threads K]
                      [--speculative 1.5] [--rounds 30] [--live]
                      [--events ev.jsonl]
  batchrep study      <smoke|fig2|tradeoff|policies|spec.json> [--fast]
                      [--out STUDY.json] [--csv points.csv] [--threads K]
                      [--seed S] [--quiet] [--events ev.jsonl]
  batchrep control    <smoke|drift|spec.json> [--fast] [--out CONTROL.json]
                      [--threads K] [--seed S] [--quiet] [--events ev.jsonl]
                      [--live] [--fault <crash|respawn|slowdown|mixed|plan.json>]
  batchrep chaos      <smoke|fig2|spec.json> [--fast] [--out CHAOS.json]
                      [--threads K] [--seed S] [--quiet] [--events ev.jsonl]
  batchrep integrity  <smoke|fig2|spec.json> [--fast] [--out INTEGRITY.json]
                      [--threads K] [--seed S] [--quiet] [--events ev.jsonl]
  batchrep obs        summarize <events.jsonl>
  batchrep simulate   [--config f] [--n-workers 12] [--n-batches 4] [--policy p]
                      [--service spec] [--trials 100000] [--seed 42]
                      [--overlapping] [--no-cancel] [--speculative 1.5]
  batchrep experiment <fig2|policies|spectrum|ablations|extensions|control|live|all>
                      [--out results] [--trials 100000] [--seed 42] [--live]
  batchrep train      [--config f] [--steps 200] [--lr 0.3] [--mock] [...]
  batchrep mapsum     [--config f] [--mock] [...]
  batchrep trace      [--n 100000] [--seed 42] [--out trace.csv]
                      [--p-enter 0.0026] [--p-exit 0.05] [--slowdown 8]
  batchrep conformance [--fast|--long] [--scenarios N] [--mc-trials N]
                      [--des-trials N] [--live-rounds N] [--threads K]
                      [--seed S] [--no-live] [--corpus f] [--no-corpus]
  batchrep bench-mc   [--trials N] [--threads K] [--out BENCH_mc.json] [--fast]
  batchrep bench-des  [--trials N] [--threads K] [--out BENCH_des.json] [--fast]
  batchrep lint       [--root rust/] [--baseline lint/baseline.json]
                      [--update-baseline] [--json LINT.json]

Config keys (file or --key value): n_workers, n_batches, policy, service,
batch_model, overlapping, cancellation, speculative, k_of_b, seed, trials,
artifacts_dir, time_scale, kernel, dim, n_samples, steps, relaunch_factor,
max_relaunches.
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Load config file + apply CLI overrides.
fn load_config(args: &Args) -> anyhow::Result<SystemConfig> {
    let mut cfg = match args.get::<String>("config")? {
        Some(path) => SystemConfig::from_file(std::path::Path::new(&path))?,
        None => SystemConfig::default(),
    };
    // CLI overrides use dashed names: --n-workers → n_workers.
    let keys = [
        "n_workers", "n_batches", "policy", "service", "batch_model", "speculative",
        "k_of_b", "seed", "trials", "artifacts_dir", "time_scale", "kernel", "dim",
        "n_samples", "steps", "relaunch_factor", "max_relaunches",
    ];
    for key in keys {
        let dashed = key.replace('_', "-");
        if let Some(v) = args.get::<String>(&dashed)? {
            let tv = if let Ok(i) = v.parse::<i64>() {
                TomlValue::Int(i)
            } else if let Ok(f) = v.parse::<f64>() {
                TomlValue::Float(f)
            } else {
                TomlValue::Str(v)
            };
            cfg.apply_kv(key, &tv)?;
        }
    }
    if args.flag("overlapping") {
        cfg.overlapping = true;
    }
    if args.flag("no-cancel") {
        cfg.cancellation = false;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// RAII owner of the process-wide event sink behind `--events <path>`:
/// installs the JSON-lines sink before the run and uninstalls it (final
/// counters event + flush) on every exit path, including errors.
struct EventsGuard(bool);

impl EventsGuard {
    fn install(path: Option<&str>) -> anyhow::Result<EventsGuard> {
        match path {
            Some(p) => {
                batchrep::obs::install_file(std::path::Path::new(p))?;
                Ok(EventsGuard(true))
            }
            None => Ok(EventsGuard(false)),
        }
    }
}

impl Drop for EventsGuard {
    fn drop(&mut self) {
        if self.0 {
            batchrep::obs::uninstall();
        }
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    match args.subcommand() {
        Some("analyze") => cmd_analyze(&args),
        Some("evaluate") => cmd_evaluate(&args),
        Some("study") => cmd_study(&args),
        Some("control") => cmd_control(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("integrity") => cmd_integrity(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("train") => cmd_train(&args),
        Some("mapsum") => cmd_mapsum(&args),
        Some("trace") => cmd_trace(&args),
        Some("conformance") => cmd_conformance(&args),
        Some("bench-mc") => cmd_bench_mc(&args),
        Some("bench-des") => cmd_bench_des(&args),
        Some("obs") => cmd_obs(&args),
        Some("lint") => cmd_lint(&args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    let n = args.get_or::<u64>("n", 24)?;
    let spec_s = args.get_or::<String>("service", "sexp:1.0,0.2".into())?;
    let spec = batchrep::dist::ServiceSpec::parse(&spec_s)?;
    args.finish()?;
    let mut t = Table::new(
        &format!("Diversity–parallelism spectrum, N={n}, service {}", spec.name()),
        &["B", "g=N/B", "E[T]", "Var[T]", "Std[T]"],
    );
    for p in analysis::spectrum(n, &spec)? {
        t.row(vec![
            p.b.to_string(),
            p.g.to_string(),
            fmt_f(p.stats.mean, 4),
            fmt_f(p.stats.var, 4),
            fmt_f(p.stats.stddev(), 4),
        ]);
    }
    t.print();
    println!(
        "mean-optimal B* = {}   variance-optimal B = {}",
        analysis::optimum_b(n, &spec)?,
        analysis::optimum_b_variance(n, &spec)?
    );
    Ok(())
}

/// The unified entry point: one scenario, any backend(s) — planned and
/// executed as a one-point study, so dedup/canonicalization and the
/// shared pool serve the CLI exactly like the experiment drivers.
fn cmd_evaluate(args: &Args) -> anyhow::Result<()> {
    let which = args.get_or::<String>("backend", "all".into())?;
    let rounds = args.get_or::<u64>("rounds", 30)?;
    let threads = args.get_or::<usize>("threads", MonteCarloEvaluator::auto_threads())?;
    let check = args.flag("cross-check");
    let include_live = args.flag("live") || which == "live";
    let events = args.get::<String>("events")?;
    let cfg = load_config(args)?;
    args.finish()?;
    let _events = EventsGuard::install(events.as_deref())?;
    // Validate the config the same way the direct scenario path would
    // (overlapping-vs-policy conflicts, k_of_b bounds, ...).
    let scn = cfg.scenario()?;
    println!(
        "scenario: N={} B={} policy={} service={} model={} redundancy={:?} seed={}",
        scn.n_workers(),
        scn.assignment.n_batches,
        scn.policy.name(),
        cfg.service.name(),
        cfg.batch_model.name(),
        scn.redundancy,
        cfg.seed
    );

    let mut backends: Vec<BackendSel> = match which.as_str() {
        "analytic" => vec![BackendSel::Analytic],
        "montecarlo" => vec![BackendSel::MonteCarlo],
        "des" => vec![BackendSel::Des],
        "live" => vec![BackendSel::Live],
        "all" => {
            let mut v = vec![BackendSel::Analytic, BackendSel::MonteCarlo, BackendSel::Des];
            if include_live {
                v.push(BackendSel::Live);
            }
            v
        }
        other => anyhow::bail!("unknown backend '{other}' (analytic|montecarlo|des|live|all)"),
    };
    if check {
        // The cross-check gate always compares analytic vs montecarlo.
        for b in [BackendSel::Analytic, BackendSel::MonteCarlo] {
            if !backends.contains(&b) {
                backends.push(b);
            }
        }
    }

    let pjrt = batchrep::runtime::default_artifact_dir().join("manifest.json").exists()
        && cfg!(feature = "pjrt");
    let spec = StudySpec {
        n_workers: vec![cfg.n_workers],
        batches: BatchAxis::Explicit(vec![cfg.n_batches]),
        policies: vec![cfg.replication_policy()],
        services: vec![batchrep::dist::BatchService {
            spec: cfg.service.clone(),
            model: cfg.batch_model,
        }],
        redundancy: vec![if cfg.speculative > 0.0 {
            RedundancyAxis::Speculative(cfg.speculative)
        } else {
            RedundancyAxis::Upfront
        }],
        k_targets: vec![if cfg.k_of_b > 0 {
            KTarget::Exact(cfg.k_of_b)
        } else {
            KTarget::Full
        }],
        backends,
        mc_trials: cfg.trials.max(1),
        des_trials: (cfg.trials / 5).max(1),
        live_rounds: rounds,
        des_cancellation: cfg.cancellation,
        live: LiveKnobs {
            time_scale: cfg.time_scale,
            n_samples: cfg.n_samples,
            dim: cfg.dim,
            pjrt,
            artifacts_dir: Some(cfg.artifacts_dir.clone()),
            cancellation: cfg.cancellation,
        },
        seed: cfg.seed,
        ..StudySpec::base("evaluate")
    };
    let mut plan = spec.compile()?;
    // CLI contract: `--seed` *is* the scenario seed, so `evaluate`
    // stays bit-comparable with `batchrep simulate --seed` and prior
    // releases (the planner's derived seeds exist for multi-point
    // studies). The one-point grid is served by exactly the scenario
    // the config describes — including its seed-derived assignment.
    for cell in &mut plan.cells {
        cell.scenario = scn.clone();
    }
    plan.scenarios = vec![scn.clone()];
    let report = batchrep::study::execute(&plan, threads, &mut |_, _, _, _| {})?;

    let mut t = Table::new(
        "Completion time, one scenario across evaluator backends",
        &["backend", "E[T]", "ci95", "Var[T]", "p50", "p99", "busy cost", "samples"],
    );
    for cell in &report.cells {
        match cell.stats() {
            Some(st) => {
                let q = |q: f64| {
                    st.quantile(q).map(|v| fmt_f(v, 4)).unwrap_or_else(|| "-".into())
                };
                t.row(vec![
                    cell.backend.name().to_string(),
                    fmt_f(st.mean, 4),
                    fmt_f(st.ci95(), 4),
                    fmt_f(st.variance, 4),
                    q(0.5),
                    q(0.99),
                    st.cost.map(|c| fmt_f(c.busy, 3)).unwrap_or_else(|| "-".into()),
                    st.samples.to_string(),
                ]);
            }
            None => {
                t.row(vec![
                    cell.backend.name().to_string(),
                    format!("n/a ({})", cell.refusal().unwrap_or("refused")),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
            }
        }
    }
    t.print();

    if check {
        let an = report
            .stats_where(&|c| c.backend == BackendSel::Analytic)?
            .clone();
        let mc = report
            .stats_where(&|c| c.backend == BackendSel::MonteCarlo)?
            .clone();
        let ck = cross_check_stats("analytic", "montecarlo", an, mc)?;
        println!(
            "cross-check analytic vs montecarlo: |diff| {:.6} <= tol {:.6}  OK",
            ck.mean_diff, ck.tolerance
        );
    }
    Ok(())
}

/// The declarative sweep entry point: load a preset or spec file,
/// compile it into a deduplicated plan, execute on the shared pool with
/// streaming progress, write + validate the STUDY artifact, optionally
/// emit CSV.
fn cmd_study(args: &Args) -> anyhow::Result<()> {
    let which = args
        .positionals
        .get(1)
        .cloned()
        .ok_or_else(|| {
            anyhow::anyhow!(
                "usage: batchrep study <spec.json|{}> [--fast] [--out f] [--csv f]",
                StudySpec::preset_names().join("|")
            )
        })?;
    let fast = args.flag("fast") || std::env::var("BATCHREP_BENCH_FAST").is_ok();
    let quiet = args.flag("quiet");
    let threads =
        args.get_or::<usize>("threads", batchrep::evaluator::auto_threads())?;
    let seed = args.get::<u64>("seed")?;
    let csv = args.get::<String>("csv")?;
    let mut spec = StudySpec::load(&which)?;
    if let Some(s) = seed {
        spec.seed = s;
    }
    if fast {
        spec = spec.fast();
    }
    let out = args.get_or::<String>("out", format!("STUDY_{}.json", spec.name))?;
    let events = args.get::<String>("events")?;
    args.finish()?;
    let _events = EventsGuard::install(events.as_deref())?;

    let plan = spec.compile()?;
    println!(
        "study '{}': {} axis points -> {} unique cells ({} deduplicated away, {} \
         analytic / {} montecarlo / {} des / {} live), seed {}",
        spec.name,
        plan.axis_points(),
        plan.cells.len(),
        plan.deduped_points(),
        plan.backend_cells(BackendSel::Analytic),
        plan.backend_cells(BackendSel::MonteCarlo),
        plan.backend_cells(BackendSel::Des),
        plan.backend_cells(BackendSel::Live),
        spec.seed
    );
    let timer = batchrep::util::Timer::start();
    let report = batchrep::study::execute(&plan, threads, &mut |cell, res, done, total| {
        if quiet {
            return;
        }
        match res.stats() {
            Some(st) => println!(
                "  [{done}/{total}] {:<10} {}  E[T] {:.4}  ci95 {:.4}",
                cell.backend.name(),
                cell.key,
                st.mean,
                st.ci95()
            ),
            None => println!(
                "  [{done}/{total}] {:<10} {}  refused: {}",
                cell.backend.name(),
                cell.key,
                res.refusal().unwrap_or("(no message)")
            ),
        }
    })?;
    let elapsed = timer.secs();

    let path = std::path::Path::new(&out);
    report.write(path)?;
    // The CI gate: a malformed artifact is an error, not a warning.
    batchrep::study::validate_file(path)?;
    if let Some(csv_path) = csv {
        report.write_csv(std::path::Path::new(&csv_path))?;
        println!("csv points written to {csv_path}");
    }

    let mut t = Table::new(
        &format!("study '{}' — plan and outcome", spec.name),
        &["metric", "value"],
    );
    t.row(vec!["axis points".into(), report.axis_points.to_string()]);
    t.row(vec!["unique cells".into(), report.unique_cells.to_string()]);
    t.row(vec!["deduplicated points".into(), report.deduped_points.to_string()]);
    t.row(vec!["refused cells".into(), report.refused_cells.to_string()]);
    t.row(vec!["threads".into(), threads.to_string()]);
    t.row(vec!["elapsed".into(), format!("{elapsed:.3}s")]);
    t.print();
    println!("study artifact written to {out} (schema v{})", batchrep::study::SCHEMA_VERSION);
    Ok(())
}

fn cmd_control(args: &Args) -> anyhow::Result<()> {
    use batchrep::control::ControlSpec;
    let which = args.positionals.get(1).cloned().ok_or_else(|| {
        anyhow::anyhow!(
            "usage: batchrep control <spec.json|{}> [--fast] [--out f] [--live [--fault p]]",
            ControlSpec::preset_names().join("|")
        )
    })?;
    let fast = args.flag("fast") || std::env::var("BATCHREP_BENCH_FAST").is_ok();
    let quiet = args.flag("quiet");
    let live = args.flag("live");
    let fault_which = args.get::<String>("fault")?;
    anyhow::ensure!(
        fault_which.is_none() || live,
        "--fault requires --live (the simulated study has no cluster to inject into)"
    );
    let fault = match &fault_which {
        Some(w) => Some(batchrep::fault::FaultPlan::load(w)?),
        None => None,
    };
    let threads = args.get_or::<usize>("threads", batchrep::evaluator::auto_threads())?;
    let seed = args.get::<u64>("seed")?;
    let mut spec = ControlSpec::load(&which)?;
    if let Some(s) = seed {
        spec.seed = s;
    }
    if fast {
        spec = spec.fast();
    }
    let default_out = if live {
        format!("CONTROL_{}_live.json", spec.name)
    } else {
        format!("CONTROL_{}.json", spec.name)
    };
    let out = args.get_or::<String>("out", default_out)?;
    let events = args.get::<String>("events")?;
    args.finish()?;
    let _events = EventsGuard::install(events.as_deref())?;

    println!(
        "control '{}'{}: N={} objective={} fit={} prior={} phases={} epochs={} \
         rounds/epoch={} replicates={} seed={}{}",
        spec.name,
        if live { " (live coordinator)" } else { "" },
        spec.n_workers,
        spec.objective.name(),
        spec.kind.name(),
        spec.prior.name(),
        spec.phases.len(),
        spec.epochs,
        spec.rounds_per_epoch,
        if live { 1 } else { spec.replicates },
        spec.seed,
        fault.as_ref().map(|p| format!(" fault-plan={}", p.name)).unwrap_or_default()
    );
    let timer = batchrep::util::Timer::start();
    let report = if live {
        batchrep::control::run_live(&spec, fault.as_ref())?
    } else {
        spec.run(threads)?
    };
    let elapsed = timer.secs();

    let path = std::path::Path::new(&out);
    report.write(path)?;
    // The CI gate: a malformed artifact is an error, not a warning.
    batchrep::control::validate_file(path)?;

    if !quiet {
        let mut t = Table::new(
            &format!("control '{}' — regret vs oracle per epoch", spec.name),
            &["epoch", "oracle B", "mean B", "frac@oracle", "mean regret", "replans", "drift"],
        );
        for e in &report.epochs {
            t.row(vec![
                e.epoch.to_string(),
                e.oracle_b.to_string(),
                fmt_f(e.mean_b, 2),
                fmt_f(e.frac_oracle, 2),
                fmt_f(e.mean_regret, 4),
                e.replans.to_string(),
                e.drift_replans.to_string(),
            ]);
        }
        t.print();
    }
    println!(
        "final frac@oracle {:.2}, final rel regret {:.4}, {} decisions, {:.3}s",
        report.final_frac_oracle,
        report.final_mean_rel_regret,
        report.decisions.len(),
        elapsed
    );
    println!("control artifact written to {out} (schema v{})", batchrep::control::SCHEMA_VERSION);
    Ok(())
}

/// The chaos gate: replay a declarative fault plan through the
/// fault-aware event engine across replicates, aggregate the recovery
/// trajectory (MTTR, rounds-to-recover, degraded throughput), write a
/// CHAOS artifact, and fail if it does not validate against the schema.
/// Bit-deterministic per seed for any `--threads`.
fn cmd_chaos(args: &Args) -> anyhow::Result<()> {
    use batchrep::fault::ChaosSpec;
    let which = args.positionals.get(1).cloned().ok_or_else(|| {
        anyhow::anyhow!(
            "usage: batchrep chaos <spec.json|{}> [--fast] [--out f]",
            ChaosSpec::preset_names().join("|")
        )
    })?;
    let fast = args.flag("fast") || std::env::var("BATCHREP_BENCH_FAST").is_ok();
    let quiet = args.flag("quiet");
    let threads = args.get_or::<usize>("threads", batchrep::evaluator::auto_threads())?;
    let seed = args.get::<u64>("seed")?;
    let mut spec = ChaosSpec::load(&which)?;
    if let Some(s) = seed {
        spec.seed = s;
    }
    if fast {
        spec = spec.fast();
    }
    let out = args.get_or::<String>("out", format!("CHAOS_{}.json", spec.name))?;
    let events = args.get::<String>("events")?;
    args.finish()?;
    let _events = EventsGuard::install(events.as_deref())?;

    println!(
        "chaos '{}': N={} B={} service={} plan={} ({} events) rounds={} replicates={} seed={}",
        spec.name,
        spec.n_workers,
        spec.n_batches,
        spec.service.name(),
        spec.plan.name,
        spec.plan.events.len(),
        spec.rounds,
        spec.replicates,
        spec.seed
    );
    let timer = batchrep::util::Timer::start();
    let report = batchrep::fault::run_chaos(&spec, threads)?;
    let elapsed = timer.secs();

    let path = std::path::Path::new(&out);
    report.write(path)?;
    // The CI gate: a malformed artifact is an error, not a warning.
    batchrep::fault::validate_file(path)?;

    if !quiet {
        let mut t = Table::new(
            &format!("chaos '{}' — fault totals and recovery", spec.name),
            &["metric", "value"],
        );
        t.row(vec!["crashes".into(), report.total_crashes.to_string()]);
        t.row(vec!["respawns".into(), report.total_respawns.to_string()]);
        t.row(vec!["relaunches".into(), report.total_relaunches.to_string()]);
        t.row(vec!["degradations".into(), report.total_degradations.to_string()]);
        t.row(vec!["dropped tasks".into(), report.total_dropped.to_string()]);
        t.row(vec!["MTTR (rounds)".into(), fmt_f(report.mttr_rounds, 2)]);
        t.row(vec!["rounds to recover".into(), report.rounds_to_recover.to_string()]);
        t.row(vec!["degraded round frac".into(), fmt_f(report.degraded_round_frac, 3)]);
        t.row(vec![
            "mean completion (normal)".into(),
            fmt_f(report.mean_completion_normal, 4),
        ]);
        t.row(vec![
            "mean completion (degraded)".into(),
            fmt_f(report.mean_completion_degraded, 4),
        ]);
        t.row(vec!["elapsed".into(), format!("{elapsed:.3}s")]);
        t.print();
    }
    println!("chaos artifact written to {out} (schema v{})", batchrep::fault::SCHEMA_VERSION);
    Ok(())
}

/// The integrity gate: sweep vote size `m` × corruption probability
/// through the verified event engine with a single corrupt worker,
/// aggregate detection rate, false positives, quarantine latency and
/// the m-of-g completion overhead, write an INTEGRITY artifact, and
/// fail if it does not validate against the schema. Bit-deterministic
/// per seed for any `--threads`.
fn cmd_integrity(args: &Args) -> anyhow::Result<()> {
    use batchrep::fault::IntegritySpec;
    let which = args.positionals.get(1).cloned().ok_or_else(|| {
        anyhow::anyhow!(
            "usage: batchrep integrity <spec.json|{}> [--fast] [--out f]",
            IntegritySpec::preset_names().join("|")
        )
    })?;
    let fast = args.flag("fast") || std::env::var("BATCHREP_BENCH_FAST").is_ok();
    let quiet = args.flag("quiet");
    let threads = args.get_or::<usize>("threads", batchrep::evaluator::auto_threads())?;
    let seed = args.get::<u64>("seed")?;
    let mut spec = IntegritySpec::load(&which)?;
    if let Some(s) = seed {
        spec.seed = s;
    }
    if fast {
        spec = spec.fast();
    }
    let out = args.get_or::<String>("out", format!("INTEGRITY_{}.json", spec.name))?;
    let events = args.get::<String>("events")?;
    args.finish()?;
    let _events = EventsGuard::install(events.as_deref())?;

    println!(
        "integrity '{}': N={} B={} service={} ms={:?} probs={:?} strikes={} \
         rounds={} replicates={} seed={}",
        spec.name,
        spec.n_workers,
        spec.n_batches,
        spec.service.name(),
        spec.ms,
        spec.probs,
        spec.strikes,
        spec.rounds,
        spec.replicates,
        spec.seed
    );
    let timer = batchrep::util::Timer::start();
    let report = batchrep::fault::run_integrity(&spec, threads)?;
    let elapsed = timer.secs();

    let path = std::path::Path::new(&out);
    report.write(path)?;
    // The CI gate: a malformed artifact is an error, not a warning.
    batchrep::fault::integrity::validate_file(path)?;

    if !quiet {
        let mut t = Table::new(
            &format!("integrity '{}' — m-of-g voting vs silent corruption", spec.name),
            &[
                "m", "prob", "corrupt", "flagged", "quar", "detect", "false+",
                "rnds→quar", "E[T]", "overhead",
            ],
        );
        for c in &report.cells {
            t.row(vec![
                c.m.to_string(),
                fmt_f(c.prob, 2),
                c.corrupted.to_string(),
                c.flagged.to_string(),
                c.quarantined.to_string(),
                fmt_f(c.detection_rate, 3),
                c.false_positive_flags.to_string(),
                c.rounds_to_quarantine.to_string(),
                fmt_f(c.mean_completion, 4),
                fmt_f(c.latency_overhead, 4),
            ]);
        }
        t.print();
        println!("elapsed {elapsed:.3}s");
    }
    println!(
        "integrity artifact written to {out} (schema v{})",
        batchrep::fault::integrity::SCHEMA_VERSION
    );
    Ok(())
}

/// Summarize + schema-validate a structured event log (`batchrep obs
/// summarize <events.jsonl>`): overview, per-`sub/kind` event counts,
/// per-span time breakdown, the straggler/relaunch histogram, and the
/// final counters snapshot. A malformed log is an error, not a warning
/// — this is the same gate ci.sh runs on the smoke event artifact.
fn cmd_obs(args: &Args) -> anyhow::Result<()> {
    let verb = args.positionals.get(1).cloned();
    anyhow::ensure!(
        verb.as_deref() == Some("summarize"),
        "usage: batchrep obs summarize <events.jsonl>"
    );
    let path = args
        .positionals
        .get(2)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("usage: batchrep obs summarize <events.jsonl>"))?;
    args.finish()?;
    let s = batchrep::obs::validate_file(std::path::Path::new(&path))?;

    let mut t = Table::new(&format!("event log {path} — overview"), &["metric", "value"]);
    t.row(vec!["events".into(), s.lines.to_string()]);
    t.row(vec![
        "subsystems".into(),
        s.subsystems.iter().cloned().collect::<Vec<_>>().join(", "),
    ]);
    t.row(vec!["duration (s)".into(), fmt_f(s.duration_s(), 3)]);
    if s.live_rounds > 0 {
        t.row(vec!["live rounds".into(), s.live_rounds.to_string()]);
    }
    t.print();

    let mut t = Table::new("events by subsystem/kind", &["event", "count"]);
    for (k, n) in &s.event_counts {
        t.row(vec![k.clone(), n.to_string()]);
    }
    t.print();

    if !s.spans.is_empty() {
        let mut spans: Vec<_> = s.spans.iter().collect();
        spans.sort_by(|a, b| b.1.total_s.total_cmp(&a.1.total_s));
        let mut t = Table::new(
            "span time breakdown (heaviest first)",
            &["span", "count", "total (s)", "mean (s)", "max (s)"],
        );
        for (name, agg) in spans {
            t.row(vec![
                name.clone(),
                agg.count.to_string(),
                fmt_f(agg.total_s, 4),
                fmt_f(agg.total_s / agg.count as f64, 6),
                fmt_f(agg.max_s, 6),
            ]);
        }
        t.print();
    }

    if !s.relaunch_hist.is_empty() {
        let mut t = Table::new(
            "straggler/relaunch histogram (relaunches per live round)",
            &["relaunches", "rounds"],
        );
        for (k, n) in &s.relaunch_hist {
            t.row(vec![k.to_string(), n.to_string()]);
        }
        t.print();
    }

    if !s.counters.is_empty() {
        let mut t = Table::new("final counters", &["counter", "value"]);
        for (k, n) in &s.counters {
            t.row(vec![k.clone(), n.to_string()]);
        }
        t.print();
    }

    println!("event log OK: {} events, schema v{}", s.lines, batchrep::obs::SCHEMA_VERSION);
    Ok(())
}

/// The determinism gate: scan `rust/src/**/*.rs` with the in-crate
/// static analyzer (rules D1–D6, see README "Static analysis") and fail
/// on any finding not absorbed by the baseline or an inline
/// `// lint:allow(RULE): reason` suppression. `--update-baseline`
/// rewrites the baseline to grandfather the current findings instead.
fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    use batchrep::lint;
    let defaults = lint::LintConfig::default();
    let root = args
        .get::<String>("root")?
        .map(std::path::PathBuf::from)
        .unwrap_or(defaults.root);
    let baseline = args
        .get::<String>("baseline")?
        .map(std::path::PathBuf::from)
        .or(defaults.baseline);
    let update = args.flag("update-baseline");
    let json_out = args.get::<String>("json")?;
    args.finish()?;

    if update {
        let path = baseline
            .ok_or_else(|| anyhow::anyhow!("--update-baseline requires a baseline path"))?;
        let cfg = lint::LintConfig { root, baseline: None };
        let report = lint::run(&cfg)?;
        let bl = lint::baseline::Baseline::from_findings(&report.findings);
        std::fs::write(&path, format!("{}\n", bl.to_json()))
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
        println!(
            "baseline updated: {} absorbed finding(s) across {} file(s) -> {}",
            report.findings.len(),
            report.files_scanned,
            path.display()
        );
        return Ok(());
    }

    let cfg = lint::LintConfig { root, baseline };
    let report = lint::run(&cfg)?;
    if let Some(out) = json_out {
        let j = lint::report_json(&report);
        lint::validate_json(&j)?;
        std::fs::write(&out, format!("{j}\n"))
            .map_err(|e| anyhow::anyhow!("writing {out}: {e}"))?;
        println!("wrote {out}");
    }
    for f in &report.findings {
        println!("{f}");
    }
    anyhow::ensure!(
        report.findings.is_empty(),
        "lint: {} finding(s) in {} file(s) ({} baselined) — fix, suppress with a reasoned \
         `// lint:allow(RULE): ...`, or run `batchrep lint --update-baseline`",
        report.findings.len(),
        report.files_scanned,
        report.baselined
    );
    println!(
        "lint OK: {} files scanned, 0 findings ({} baselined)",
        report.files_scanned, report.baselined
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    // Back-compat: --speculative also works as the config key.
    let speculative = args.get::<f64>("speculative")?;
    let cfg = load_config(args)?;
    args.finish()?;
    let mut scn = cfg.scenario()?;
    if let Some(df) = speculative {
        scn = scn.with_redundancy(Redundancy::Speculative { deadline_factor: df });
    }

    println!(
        "scenario: N={} B={} policy={} layout={} service={} model={}",
        cfg.n_workers,
        scn.assignment.n_batches,
        scn.policy.name(),
        if cfg.overlapping { "overlapping" } else { "disjoint" },
        cfg.service.name(),
        cfg.batch_model.name()
    );

    // Monte-Carlo backend (models upfront replication; auto-threaded).
    let upfront = scn.clone().with_redundancy(Redundancy::Upfront);
    let mc = MonteCarloEvaluator { trials: cfg.trials, ..MonteCarloEvaluator::default() };
    let st = mc.evaluate(&upfront)?;
    let mut t = Table::new("Monte-Carlo completion time", &["metric", "value"]);
    t.row(vec!["trials".into(), st.samples.to_string()]);
    t.row(vec!["mean".into(), fmt_f(st.mean, 5)]);
    t.row(vec!["ci95".into(), fmt_f(st.ci95(), 5)]);
    t.row(vec!["variance".into(), fmt_f(st.variance, 5)]);
    t.row(vec!["p50".into(), fmt_f(st.quantile(0.5).unwrap_or(f64::NAN), 5)]);
    t.row(vec!["p99".into(), fmt_f(st.quantile(0.99).unwrap_or(f64::NAN), 5)]);
    if let Ok(cf) = AnalyticEvaluator.evaluate(&upfront) {
        t.row(vec!["closed-form mean".into(), fmt_f(cf.mean, 5)]);
        t.row(vec!["closed-form variance".into(), fmt_f(cf.variance, 5)]);
    }
    t.print();

    // Event-engine backend (models the scenario's redundancy mode and
    // accounts cost).
    let des = DesEvaluator {
        trials: (cfg.trials / 10).max(1),
        cancellation: cfg.cancellation,
        ..DesEvaluator::default()
    };
    let st2 = des.evaluate(&scn)?;
    let cost = st2.cost.expect("des backend reports cost");
    let mut t2 = Table::new("Event-engine (cost accounting)", &["metric", "value"]);
    t2.row(vec!["completion mean".into(), fmt_f(st2.mean, 5)]);
    t2.row(vec!["busy worker-seconds".into(), fmt_f(cost.busy, 5)]);
    t2.row(vec!["wasted worker-seconds".into(), fmt_f(cost.wasted, 5)]);
    t2.print();
    Ok(())
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    let which = args
        .positionals
        .get(1)
        .map(String::as_str)
        .unwrap_or("all")
        .to_string();
    let ctx = ExpContext {
        out_dir: args.get_or::<String>("out", "results".into())?.into(),
        trials: args.get_or::<u64>("trials", 100_000)?,
        seed: args.seed(42)?,
    };
    let include_live = args.flag("live");
    args.finish()?;
    std::fs::create_dir_all(&ctx.out_dir)?;
    match which.as_str() {
        "fig2" => experiments::fig2::run(&ctx)?,
        "policies" => experiments::policies::run(&ctx)?,
        "spectrum" => experiments::spectrum::run(&ctx)?,
        "ablations" => experiments::ablations::run(&ctx)?,
        "extensions" => experiments::extensions::run(&ctx)?,
        "control" => experiments::control_loop::run(&ctx)?,
        "live" => experiments::live::run(&ctx)?,
        "all" => experiments::run_all(&ctx, include_live)?,
        other => anyhow::bail!("unknown experiment '{other}'"),
    };
    println!("results written to {}", ctx.out_dir.display());
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let lr = args.get_or::<f64>("lr", 0.3)?;
    let steps_override = args.get::<u64>("steps")?;
    let mock = args.flag("mock");
    let cfg = load_config(args)?;
    args.finish()?;
    let steps = steps_override.unwrap_or(cfg.steps);
    let backend = if mock { Backend::Mock } else { Backend::Pjrt };
    println!(
        "training: N={} B={} policy={} service={} steps={} lr={} backend={:?}",
        cfg.n_workers,
        cfg.n_batches,
        cfg.policy.name(),
        cfg.service.name(),
        steps,
        lr,
        backend
    );
    let mut coord = Coordinator::new(cfg, backend)?;
    let mut report = coord.run_training(steps, lr)?;
    for (i, loss) in report.loss_curve.iter().enumerate() {
        if i < 5 || i % (steps as usize / 10).max(1) == 0 || i + 1 == steps as usize {
            println!("step {i:>5}  loss {loss:.6}");
        }
    }
    println!("‖w − w*‖ = {:.5}", report.dist_to_w_star);
    report.metrics.summary_table("training run").print();
    coord.shutdown();
    Ok(())
}

fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    use batchrep::trace::{generate_markov_trace, save_trace, MarkovTraceParams};
    let n = args.get_or::<usize>("n", 100_000)?;
    let seed = args.seed(42)?;
    let out = args.get_or::<String>("out", "trace.csv".into())?;
    let defaults = MarkovTraceParams::default();
    let params = MarkovTraceParams {
        p_enter: args.get_or::<f64>("p-enter", defaults.p_enter)?,
        p_exit: args.get_or::<f64>("p-exit", defaults.p_exit)?,
        slowdown: args.get_or::<f64>("slowdown", defaults.slowdown)?,
        base_mu: args.get_or::<f64>("mu", defaults.base_mu)?,
        base_delta: args.get_or::<f64>("delta", defaults.base_delta)?,
    };
    args.finish()?;
    let t = generate_markov_trace(&params, n, seed);
    let mean = t.iter().sum::<f64>() / t.len() as f64;
    let max = batchrep::util::stats::fold_max_total(t.iter().cloned());
    save_trace(std::path::Path::new(&out), &t)?;
    println!(
        "wrote {n} per-unit service times to {out} (mean {mean:.4}, max {max:.4}); \
         replay with service trace files via batchrep::trace::load_trace"
    );
    Ok(())
}

/// The conformance gate: sweep deterministic anchors plus generated
/// scenarios through every applicable backend pair with stderr-scaled
/// z-bound tolerances. Exits nonzero on any disagreement; the failure
/// output carries the shrunk minimal case and its `BATCHREP_PROP_SEED`
/// replay seed.
fn cmd_conformance(args: &Args) -> anyhow::Result<()> {
    let fast = args.flag("fast") || std::env::var("BATCHREP_BENCH_FAST").is_ok();
    let long = args.flag("long");
    anyhow::ensure!(!(fast && long), "--fast and --long are mutually exclusive");
    let mut opts = if fast {
        batchrep::conformance::MatrixOptions::fast()
    } else if long {
        batchrep::conformance::MatrixOptions::long()
    } else {
        batchrep::conformance::MatrixOptions::full()
    };
    opts.scenarios = args.get_or::<u64>("scenarios", opts.scenarios)?;
    opts.mc_trials = args.get_or::<u64>("mc-trials", opts.mc_trials)?;
    opts.des_trials = args.get_or::<u64>("des-trials", opts.des_trials)?;
    opts.live_rounds = args.get_or::<u64>("live-rounds", opts.live_rounds)?;
    opts.threads = args.get_or::<usize>("threads", opts.threads)?;
    opts.seed = args.get::<u64>("seed")?;
    if args.flag("no-live") {
        opts.include_live = false;
    }
    opts.corpus = if args.flag("no-corpus") {
        None
    } else {
        Some(match args.get::<String>("corpus")? {
            Some(p) => std::path::PathBuf::from(p),
            None => batchrep::conformance::default_corpus_path(),
        })
    };
    args.finish()?;
    println!(
        "conformance matrix: {} generated scenarios + anchors, mc {} / des {} trials, \
         z = {}, live {}",
        opts.scenarios,
        opts.mc_trials,
        opts.des_trials,
        opts.z,
        if opts.include_live { "on" } else { "off" }
    );
    let report = batchrep::conformance::run_matrix(&opts)?;
    let mut t = Table::new(
        "Conformance matrix — backend-pair agreement over generated scenarios",
        &["backend pair", "cells"],
    );
    t.row(vec!["analytic <-> montecarlo".into(), report.analytic_mc.to_string()]);
    t.row(vec!["analytic <-> des".into(), report.analytic_des.to_string()]);
    t.row(vec!["montecarlo <-> des".into(), report.mc_des.to_string()]);
    t.row(vec!["des <-> des-reference".into(), report.des_reference.to_string()]);
    t.row(vec!["des <-> live".into(), report.des_live.to_string()]);
    t.row(vec!["live-crash <-> analytic".into(), report.live_crash.to_string()]);
    t.row(vec!["live <-> des-fault".into(), report.live_des_fault.to_string()]);
    t.print();
    println!(
        "conformance: {} scenarios ({} corpus replays), {} cells agree \
         (worst gap/tol {:.3}); heterogeneous-speed analytic cells: {}, \
         live k-of-B cells: {}, live-crash cells: {}, live fault-plan cells: {}",
        report.scenarios,
        report.corpus_replayed,
        report.cells,
        report.worst_gap_over_tol,
        report.hetero_analytic_cells,
        report.live_k_of_b_cells,
        report.live_crash,
        report.live_des_fault
    );
    Ok(())
}

/// Monte-Carlo throughput trajectory: measure trials/sec on the fixed
/// fig2-scale reference scenario, write BENCH_mc.json, and fail if the
/// written artifact does not validate against the schema.
fn cmd_bench_mc(args: &Args) -> anyhow::Result<()> {
    let fast = args.flag("fast") || std::env::var("BATCHREP_BENCH_FAST").is_ok();
    let trials = args.get_or::<u64>("trials", if fast { 40_000 } else { 2_000_000 })?;
    let threads = args.get_or::<usize>(
        "threads",
        batchrep::evaluator::MonteCarloEvaluator::auto_threads(),
    )?;
    let out = args.get_or::<String>("out", "BENCH_mc.json".into())?;
    args.finish()?;
    let report = batchrep::benchkit::mc::run(trials, threads);
    let path = std::path::Path::new(&out);
    report.write(path)?;
    // The CI gate: a malformed artifact is an error, not a warning.
    batchrep::benchkit::mc::validate_file(path)?;
    let fmt_tps = |t: &batchrep::benchkit::mc::Throughput| format!("{:.3e}", t.trials_per_sec);
    let mut t = Table::new(
        &format!("bench-mc — {} trials on the fig2-scale reference scenario", trials),
        &["sampler", "trials/s", "elapsed"],
    );
    t.row(vec![
        "reference scalar".into(),
        fmt_tps(&report.reference_scalar),
        format!("{:.3}s", report.reference_scalar.elapsed_s),
    ]);
    t.row(vec![
        "block single-thread".into(),
        fmt_tps(&report.single_thread),
        format!("{:.3}s", report.single_thread.elapsed_s),
    ]);
    t.row(vec![
        format!("block {} threads", report.threads),
        fmt_tps(&report.multi_thread),
        format!("{:.3}s", report.multi_thread.elapsed_s),
    ]);
    t.print();
    println!(
        "speedup: block vs scalar {:.2}x, threads vs single {:.2}x — wrote {out}",
        report.speedup_block_vs_reference, report.speedup_threads_vs_single
    );
    Ok(())
}

/// DES throughput trajectory: measure trials/sec of the three engine
/// paths (reference / flat-queue single-thread / multi-thread) on the
/// fixed fig2-scale reference scenario, upfront and speculative, write
/// BENCH_des.json, and fail if the written artifact does not validate
/// against the schema.
fn cmd_bench_des(args: &Args) -> anyhow::Result<()> {
    let fast = args.flag("fast") || std::env::var("BATCHREP_BENCH_FAST").is_ok();
    let trials = args.get_or::<u64>("trials", if fast { 4_000 } else { 200_000 })?;
    let threads = args.get_or::<usize>("threads", batchrep::evaluator::auto_threads())?;
    let out = args.get_or::<String>("out", "BENCH_des.json".into())?;
    args.finish()?;
    let report = batchrep::benchkit::des::run(trials, threads);
    let path = std::path::Path::new(&out);
    report.write(path)?;
    // The CI gate: a malformed artifact is an error, not a warning.
    batchrep::benchkit::des::validate_file(path)?;
    let fmt_tps = |t: &batchrep::benchkit::mc::Throughput| format!("{:.3e}", t.trials_per_sec);
    let mut t = Table::new(
        &format!("bench-des — {} trials on the fig2-scale reference scenario", trials),
        &["mode", "engine", "trials/s", "elapsed"],
    );
    for (mode, m) in
        [("upfront", &report.upfront), ("speculative", &report.speculative)]
    {
        t.row(vec![
            mode.into(),
            "reference (heap+scalar)".into(),
            fmt_tps(&m.reference_scalar),
            format!("{:.3}s", m.reference_scalar.elapsed_s),
        ]);
        t.row(vec![
            mode.into(),
            "flat+block single-thread".into(),
            fmt_tps(&m.single_thread),
            format!("{:.3}s", m.single_thread.elapsed_s),
        ]);
        t.row(vec![
            mode.into(),
            format!("flat+block {} threads", report.threads),
            fmt_tps(&m.multi_thread),
            format!("{:.3}s", m.multi_thread.elapsed_s),
        ]);
    }
    t.print();
    println!(
        "speedup (upfront): flat vs reference {:.2}x, threads vs single {:.2}x — wrote {out}",
        report.upfront.speedup_flat_vs_reference,
        report.upfront.speedup_threads_vs_single
    );
    Ok(())
}

fn cmd_mapsum(args: &Args) -> anyhow::Result<()> {
    let mock = args.flag("mock");
    let cfg = load_config(args)?;
    args.finish()?;
    let dim = cfg.dim;
    let backend = if mock { Backend::Mock } else { Backend::Pjrt };
    let mut coord = Coordinator::new(cfg, backend)?;
    let a = vec![0.1f32; dim];
    let b = vec![0.05f32; dim];
    let total = coord.run_mapsum(a, b)?;
    println!("f(D) = {total:.6}");
    coord.metrics.summary_table("mapsum run").print();
    coord.shutdown();
    Ok(())
}
