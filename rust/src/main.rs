//! `batchrep` — CLI launcher for the System1 reproduction.
//!
//! Subcommands:
//!   analyze     closed-form diversity–parallelism spectrum (Theorems 2–4)
//!   simulate    Monte-Carlo + event-engine simulation of one scenario
//!   experiment  regenerate paper figures/tables (fig2|policies|spectrum|
//!               ablations|live|all)
//!   train       run the live distributed-SGD System1 (PJRT backend)
//!   mapsum      run one live distributed map-sum evaluation
//!
//! Global options: `--config <file.toml>` plus per-key overrides
//! (`--n-workers 24`, `--service sexp:1.0,0.2`, ...). See README.

use batchrep::analysis;
use batchrep::config::cli::Args;
use batchrep::config::toml::TomlValue;
use batchrep::config::SystemConfig;
use batchrep::coordinator::{Backend, Coordinator};
use batchrep::des::engine::{simulate_many, EngineConfig, Redundancy};
use batchrep::des::montecarlo;
use batchrep::experiments::{self, ExpContext};
use batchrep::util::table::{fmt_f, Table};

const USAGE: &str = "\
batchrep — data replication for straggler mitigation (Behrouzi-Far & Soljanin, 2019)

USAGE:
  batchrep analyze    [--n 24] [--service sexp:1.0,0.2]
  batchrep simulate   [--config f] [--n-workers 12] [--n-batches 4] [--policy p]
                      [--service spec] [--trials 100000] [--seed 42]
                      [--overlapping] [--no-cancel] [--speculative 1.5]
  batchrep experiment <fig2|policies|spectrum|ablations|extensions|live|all>
                      [--out results] [--trials 100000] [--seed 42] [--live]
  batchrep train      [--config f] [--steps 200] [--lr 0.3] [--mock] [...]
  batchrep mapsum     [--config f] [--mock] [...]
  batchrep trace      [--n 100000] [--seed 42] [--out trace.csv]
                      [--p-enter 0.0026] [--p-exit 0.05] [--slowdown 8]

Config keys (file or --key value): n_workers, n_batches, policy, service,
batch_model, overlapping, cancellation, seed, trials, artifacts_dir,
time_scale, kernel, dim, n_samples, steps.
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Load config file + apply CLI overrides.
fn load_config(args: &Args) -> anyhow::Result<SystemConfig> {
    let mut cfg = match args.get::<String>("config")? {
        Some(path) => SystemConfig::from_file(std::path::Path::new(&path))?,
        None => SystemConfig::default(),
    };
    // CLI overrides use dashed names: --n-workers → n_workers.
    let keys = [
        "n_workers", "n_batches", "policy", "service", "batch_model", "seed",
        "trials", "artifacts_dir", "time_scale", "kernel", "dim", "n_samples",
        "steps",
    ];
    for key in keys {
        let dashed = key.replace('_', "-");
        if let Some(v) = args.get::<String>(&dashed)? {
            let tv = if let Ok(i) = v.parse::<i64>() {
                TomlValue::Int(i)
            } else if let Ok(f) = v.parse::<f64>() {
                TomlValue::Float(f)
            } else {
                TomlValue::Str(v)
            };
            cfg.apply_kv(key, &tv)?;
        }
    }
    if args.flag("overlapping") {
        cfg.overlapping = true;
    }
    if args.flag("no-cancel") {
        cfg.cancellation = false;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    match args.subcommand() {
        Some("analyze") => cmd_analyze(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("train") => cmd_train(&args),
        Some("mapsum") => cmd_mapsum(&args),
        Some("trace") => cmd_trace(&args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    let n = args.get_or::<u64>("n", 24)?;
    let spec_s = args.get_or::<String>("service", "sexp:1.0,0.2".into())?;
    let spec = batchrep::dist::ServiceSpec::parse(&spec_s)?;
    args.finish()?;
    let mut t = Table::new(
        &format!("Diversity–parallelism spectrum, N={n}, service {}", spec.name()),
        &["B", "g=N/B", "E[T]", "Var[T]", "Std[T]"],
    );
    for p in analysis::spectrum(n, &spec)? {
        t.row(vec![
            p.b.to_string(),
            p.g.to_string(),
            fmt_f(p.stats.mean, 4),
            fmt_f(p.stats.var, 4),
            fmt_f(p.stats.stddev(), 4),
        ]);
    }
    t.print();
    println!(
        "mean-optimal B* = {}   variance-optimal B = {}",
        analysis::optimum_b(n, &spec),
        analysis::optimum_b_variance(n, &spec)
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let speculative = args.get::<f64>("speculative")?;
    let cfg = load_config(args)?;
    args.finish()?;
    let scn = cfg.scenario()?;

    println!(
        "scenario: N={} B={} policy={} layout={} service={} model={}",
        cfg.n_workers,
        scn.assignment.n_batches,
        cfg.policy.name(),
        if cfg.overlapping { "overlapping" } else { "disjoint" },
        cfg.service.name(),
        cfg.batch_model.name()
    );

    let mc = montecarlo::run_trials(&scn, cfg.trials, cfg.seed);
    let mut t = Table::new("Monte-Carlo completion time", &["metric", "value"]);
    t.row(vec!["trials".into(), cfg.trials.to_string()]);
    t.row(vec!["mean".into(), fmt_f(mc.mean(), 5)]);
    t.row(vec!["ci95".into(), fmt_f(mc.ci95(), 5)]);
    t.row(vec!["variance".into(), fmt_f(mc.variance(), 5)]);
    let mut samples = mc.samples.clone();
    t.row(vec!["p50".into(), fmt_f(samples.quantile(0.5), 5)]);
    t.row(vec!["p99".into(), fmt_f(samples.quantile(0.99), 5)]);
    if let Ok(cf) = analysis::completion_time_stats(
        cfg.n_workers as u64,
        scn.assignment.n_batches as u64,
        &cfg.service,
    ) {
        t.row(vec!["closed-form mean".into(), fmt_f(cf.mean, 5)]);
        t.row(vec!["closed-form variance".into(), fmt_f(cf.var, 5)]);
    }
    t.print();

    let redundancy = match speculative {
        Some(df) => Redundancy::Speculative { deadline_factor: df },
        None => Redundancy::Upfront,
    };
    let ecfg = EngineConfig { cancellation: cfg.cancellation, redundancy, ..EngineConfig::default() };
    let etrials = (cfg.trials / 10).max(1);
    let sum = simulate_many(&scn, &ecfg, etrials, cfg.seed ^ 1);
    let mut t2 = Table::new("Event-engine (cost accounting)", &["metric", "value"]);
    t2.row(vec!["completion mean".into(), fmt_f(sum.completion.mean(), 5)]);
    t2.row(vec!["busy worker-seconds".into(), fmt_f(sum.busy.mean(), 5)]);
    t2.row(vec!["wasted worker-seconds".into(), fmt_f(sum.wasted.mean(), 5)]);
    t2.row(vec![
        "events/trial".into(),
        fmt_f(sum.total_events as f64 / etrials as f64, 2),
    ]);
    t2.print();
    Ok(())
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    let which = args
        .positionals
        .get(1)
        .map(String::as_str)
        .unwrap_or("all")
        .to_string();
    let ctx = ExpContext {
        out_dir: args.get_or::<String>("out", "results".into())?.into(),
        trials: args.get_or::<u64>("trials", 100_000)?,
        seed: args.get_or::<u64>("seed", 42)?,
    };
    let include_live = args.flag("live");
    args.finish()?;
    std::fs::create_dir_all(&ctx.out_dir)?;
    match which.as_str() {
        "fig2" => experiments::fig2::run(&ctx)?,
        "policies" => experiments::policies::run(&ctx)?,
        "spectrum" => experiments::spectrum::run(&ctx)?,
        "ablations" => experiments::ablations::run(&ctx)?,
        "extensions" => experiments::extensions::run(&ctx)?,
        "live" => experiments::live::run(&ctx)?,
        "all" => experiments::run_all(&ctx, include_live)?,
        other => anyhow::bail!("unknown experiment '{other}'"),
    };
    println!("results written to {}", ctx.out_dir.display());
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let lr = args.get_or::<f64>("lr", 0.3)?;
    let steps_override = args.get::<u64>("steps")?;
    let mock = args.flag("mock");
    let cfg = load_config(args)?;
    args.finish()?;
    let steps = steps_override.unwrap_or(cfg.steps);
    let backend = if mock { Backend::Mock } else { Backend::Pjrt };
    println!(
        "training: N={} B={} policy={} service={} steps={} lr={} backend={:?}",
        cfg.n_workers,
        cfg.n_batches,
        cfg.policy.name(),
        cfg.service.name(),
        steps,
        lr,
        backend
    );
    let mut coord = Coordinator::new(cfg, backend)?;
    let report = coord.run_training(steps, lr)?;
    for (i, loss) in report.loss_curve.iter().enumerate() {
        if i < 5 || i % (steps as usize / 10).max(1) == 0 || i + 1 == steps as usize {
            println!("step {i:>5}  loss {loss:.6}");
        }
    }
    println!("‖w − w*‖ = {:.5}", report.dist_to_w_star);
    report.metrics.summary_table("training run").print();
    coord.shutdown();
    Ok(())
}

fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    use batchrep::trace::{generate_markov_trace, save_trace, MarkovTraceParams};
    let n = args.get_or::<usize>("n", 100_000)?;
    let seed = args.get_or::<u64>("seed", 42)?;
    let out = args.get_or::<String>("out", "trace.csv".into())?;
    let defaults = MarkovTraceParams::default();
    let params = MarkovTraceParams {
        p_enter: args.get_or::<f64>("p-enter", defaults.p_enter)?,
        p_exit: args.get_or::<f64>("p-exit", defaults.p_exit)?,
        slowdown: args.get_or::<f64>("slowdown", defaults.slowdown)?,
        base_mu: args.get_or::<f64>("mu", defaults.base_mu)?,
        base_delta: args.get_or::<f64>("delta", defaults.base_delta)?,
    };
    args.finish()?;
    let t = generate_markov_trace(&params, n, seed);
    let mean = t.iter().sum::<f64>() / t.len() as f64;
    let max = t.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    save_trace(std::path::Path::new(&out), &t)?;
    println!(
        "wrote {n} per-unit service times to {out} (mean {mean:.4}, max {max:.4}); \
         replay with service trace files via batchrep::trace::load_trace"
    );
    Ok(())
}

fn cmd_mapsum(args: &Args) -> anyhow::Result<()> {
    let mock = args.flag("mock");
    let cfg = load_config(args)?;
    args.finish()?;
    let dim = cfg.dim;
    let backend = if mock { Backend::Mock } else { Backend::Pjrt };
    let mut coord = Coordinator::new(cfg, backend)?;
    let a = vec![0.1f32; dim];
    let b = vec![0.05f32; dim];
    let total = coord.run_mapsum(a, b)?;
    println!("f(D) = {total:.6}");
    coord.metrics.summary_table("mapsum run").print();
    coord.shutdown();
    Ok(())
}
