//! The live System1 master (paper Fig. 1): batching unit, batch
//! assignment unit, dispatcher, aggregation unit, and result generation.
//!
//! The master owns the event loop (std threads + mpsc channels; the
//! offline environment has no tokio — see DESIGN.md §4). A *job* is one
//! round of the distributed computation (one SGD step, or one map-sum
//! evaluation). Per job the master:
//!
//! 1. samples each worker's straggle from the configured service-time
//!    distribution (size-dependent batch model, scaled by `time_scale`),
//! 2. dispatches one replica task per worker (stage-2 assignment),
//! 3. collects results; the **first** replica of each batch wins, its
//!    siblings are cancelled (when `cancellation` is on), later arrivals
//!    count as redundant,
//! 4. aggregates the winners (gradient/loss sums or map-sum scalars) and
//!    generates the round's result (SGD weight update),
//! 5. records completion-time metrics.
//!
//! Completion is declared at coverage: for disjoint layouts every batch
//! must report; overlapping layouts complete as soon as finished
//! workers' units cover the dataset. With a `k_of_b` target (the
//! gradient-coding regime — `Scenario::k_of_b` or the `k_of_b` config
//! key) the round instead completes at the **k-th finished batch**: the
//! master aggregates the earliest `k` batch results, cancels every
//! remaining replica, and counts stragglers that beat their cancel as
//! redundant.

pub mod data;

use crate::assignment::Assignment;
use crate::batching::DataLayout;
use crate::config::SystemConfig;
use crate::dist::BatchService;
use crate::metrics::{JobRecord, RunMetrics};
use crate::runtime::GradOut;
use crate::util::rng::Rng;
use crate::util::Timer;
use crate::worker::{
    spawn_worker, Compute, JobOut, JobSpec, ResultMsg, TaskMsg, WorkerHandle,
};
use data::Dataset;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// Which compute backend worker threads construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT PJRT artifacts (requires `make artifacts`).
    Pjrt,
    /// Pure-Rust mock (tests; no artifacts needed).
    Mock,
}

/// Aggregated output of one job round.
#[derive(Debug, Clone)]
pub enum RoundOutput {
    /// Gradient round: summed gradient + loss over the dataset.
    Grad(GradOut),
    /// Map-sum round: the scalar total.
    MapSum(f32),
}

/// Fault and recovery events observed during one round — the live
/// analogue of the DES engine's per-trial counters, surfaced so chaos
/// runs are debuggable from the round result alone.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RoundEvents {
    /// Workers that died this round (hand-armed or plan-scheduled).
    pub crashes: u64,
    /// Dead workers respawned at the start of this round.
    pub respawns: u64,
    /// Speculative deadline relaunches dispatched this round.
    pub relaunches: u64,
    /// Degraded-mode re-plans (assignment rebuilt onto survivors) plus
    /// detected-but-unrecoverable vote rounds (a batch whose replicas
    /// disagree with no attributable majority).
    pub degradations: u64,
    /// Tasks dropped before dispatch by the fault plan.
    pub dropped: u64,
    /// Replicas dispatched with a corruption injection this round.
    pub corrupted: u64,
    /// Replicas flagged by the m-of-g vote (disagreed with an accepted
    /// majority value).
    pub flagged: u64,
    /// Workers quarantined at the end of this round (strike budget
    /// exhausted).
    pub quarantined: u64,
}

impl RoundEvents {
    /// Whether anything fault-related happened this round.
    pub fn any(&self) -> bool {
        self.crashes
            + self.respawns
            + self.relaunches
            + self.degradations
            + self.dropped
            + self.corrupted
            + self.flagged
            + self.quarantined
            > 0
    }
}

/// Result of one job round: the aggregated output plus the round's
/// fault/recovery event counters.
#[derive(Debug, Clone)]
pub struct RoundResult {
    /// Aggregated winners (gradient sum or map-sum scalar).
    pub output: RoundOutput,
    /// Fault and recovery events observed during the round.
    pub events: RoundEvents,
}

/// Report of a training run.
#[derive(Debug, Clone)]
pub struct TrainingReport {
    /// Mean loss per step (normalized by sample count).
    pub loss_curve: Vec<f64>,
    /// Final weights.
    pub final_w: Vec<f32>,
    /// Distance to the generating weights (synthetic data).
    pub dist_to_w_star: f64,
    /// Per-job metrics.
    pub metrics: RunMetrics,
}

/// Reusable per-round working memory: cancellation tokens plus
/// generation-stamped coverage and winner maps. Allocated once at
/// construction so the live round loop (dispatch → collect → post-hoc
/// coverage validation) performs no heap allocation per round.
#[derive(Debug)]
struct RoundScratch {
    /// One cancellation token per batch, reset (not reallocated) each
    /// round.
    cancels: Vec<Arc<AtomicBool>>,
    /// `unit_covered[u] == generation` ⇔ unit `u` covered this round.
    unit_covered: Vec<u32>,
    /// `batch_won[b] == generation` ⇔ batch `b` already has a winner.
    batch_won: Vec<u32>,
    /// `batch_ok[b] == generation` ⇔ batch `b` has at least one live,
    /// non-crashing replica this round (the pre-dispatch coverage
    /// feasibility check under worker death).
    batch_ok: Vec<u32>,
    /// Slowest injected delay among the batch's dispatched completable
    /// replicas (the base of its speculative relaunch deadline).
    batch_max_delay: Vec<f64>,
    /// Wall-clock instant (round-timer seconds) by which the batch must
    /// have a winner before the coordinator relaunches it (fault mode).
    batch_deadline: Vec<f64>,
    /// Relaunch attempts already spent on the batch this round.
    batch_attempts: Vec<u32>,
    /// Collected replica results per batch awaiting the m-of-g vote
    /// (`verify_m` mode only): `(worker, output, injected_s)` in
    /// arrival order.
    batch_votes: Vec<Vec<(usize, JobOut, f64)>>,
    /// Replicas dispatched to the batch this round that have not yet
    /// reported — when it hits zero an unwon batch can collect no more
    /// votes and must be resolved with whatever arrived.
    batch_pending: Vec<u32>,
    /// Stamp of the current round; bumping it resets both maps in O(1).
    generation: u32,
}

impl RoundScratch {
    fn new(n_units: usize, n_batches: usize) -> Self {
        Self {
            cancels: (0..n_batches).map(|_| Arc::new(AtomicBool::new(false))).collect(),
            unit_covered: vec![0; n_units],
            batch_won: vec![0; n_batches],
            batch_ok: vec![0; n_batches],
            batch_max_delay: vec![0.0; n_batches],
            batch_deadline: vec![f64::INFINITY; n_batches],
            batch_attempts: vec![0; n_batches],
            batch_votes: vec![Vec::new(); n_batches],
            batch_pending: vec![0; n_batches],
            generation: 0,
        }
    }

    /// Start a new round: bump the stamp and clear the cancel tokens.
    /// Safe to call once the previous round has fully reported — every
    /// in-flight task clone of the tokens has been dropped by then.
    fn begin_round(&mut self) -> u32 {
        self.generation = self.generation.wrapping_add(1);
        if self.generation == 0 {
            // Stamp wraparound: clear once every 2^32 rounds.
            self.unit_covered.fill(0);
            self.batch_won.fill(0);
            self.batch_ok.fill(0);
            self.generation = 1;
        }
        self.batch_max_delay.fill(0.0);
        self.batch_deadline.fill(f64::INFINITY);
        self.batch_attempts.fill(0);
        for v in &mut self.batch_votes {
            v.clear();
        }
        self.batch_pending.fill(0);
        for c in &self.cancels {
            c.store(false, Ordering::Relaxed);
        }
        self.generation
    }
}

/// Floor added to every per-batch relaunch deadline: absorbs compute
/// and scheduler latency that the injected-delay scaling cannot see at
/// tiny `time_scale`.
const RELAUNCH_FLOOR_S: f64 = 0.05;

/// Grace added to the whole-round liveness bound beyond the scaled
/// slowest injected delay (covers real compute + thread scheduling).
const LIVENESS_GRACE_S: f64 = 5.0;

/// Relative agreement tolerance of the m-of-g vote. Honest replicas of
/// the same batch compute the same deterministic sums and agree
/// bit-exactly; the tolerance only absorbs backend-order float noise.
/// The injected corruption (`+1 + worker_id` per component) exceeds it
/// by orders of magnitude at any realistic output scale, so false
/// positives are structurally zero.
const VOTE_REL_TOL: f32 = 1e-4;

fn scalars_agree(a: f32, b: f32) -> bool {
    (a - b).abs() <= VOTE_REL_TOL * a.abs().max(b.abs()).max(1.0)
}

/// Whether two replica outputs count as the same value under the vote.
fn job_out_agree(a: &JobOut, b: &JobOut) -> bool {
    match (a, b) {
        (JobOut::Grad(x), JobOut::Grad(y)) => {
            x.grad.len() == y.grad.len()
                && scalars_agree(x.loss, y.loss)
                && x.grad.iter().zip(&y.grad).all(|(p, q)| scalars_agree(*p, *q))
        }
        (JobOut::MapSum(x), JobOut::MapSum(y)) => scalars_agree(*x, *y),
        _ => false,
    }
}

/// Largest agreement group among the collected votes: returns the index
/// of the group's earliest-arrived representative and the group size.
/// Ties go to the earlier arrival.
fn vote_winner(votes: &[(usize, JobOut, f64)]) -> (usize, usize) {
    let mut best = (0usize, 0usize);
    for i in 0..votes.len() {
        if (0..i).any(|j| job_out_agree(&votes[j].1, &votes[i].1)) {
            continue; // not its group's earliest representative
        }
        let size =
            (i..votes.len()).filter(|&j| job_out_agree(&votes[i].1, &votes[j].1)).count();
        if size > best.1 {
            best = (i, size);
        }
    }
    best
}

/// The live coordinator.
#[derive(Debug)]
pub struct Coordinator {
    cfg: SystemConfig,
    assignment: Assignment,
    layout: DataLayout,
    service: BatchService,
    dataset: Arc<Dataset>,
    workers: Vec<WorkerHandle>,
    results: Receiver<ResultMsg>,
    /// Sender side of the result channel, kept so respawned workers can
    /// be wired into the same collector.
    res_tx: Sender<ResultMsg>,
    /// Which compute backend replacement workers construct.
    backend: Backend,
    rng: Rng,
    next_job: u64,
    /// Compiled fault plan driving scheduled crashes, slowdowns, and
    /// task drops (`None` = no fault injection).
    fault: Option<crate::fault::CompiledPlan>,
    /// Rounds run so far (the fault plan's clock).
    round_index: u64,
    /// `respawn_at[w] = Some(r)` ⇔ dead worker `w` is respawned at the
    /// start of round `r`.
    respawn_at: Vec<Option<u64>>,
    /// Respawns already spent per worker (drives the exponential
    /// backoff between attempts).
    respawn_attempts: Vec<u32>,
    /// Per-worker speed multipliers for the injected delays (`None` =
    /// homogeneous) — the live analogue of `Scenario::worker_speeds`.
    speeds: Option<Vec<f64>>,
    /// Partial-aggregation target: the round completes at the k-th
    /// finished batch (`None` = full coverage) — the live analogue of
    /// `Scenario::k_of_b`.
    k_of_b: Option<usize>,
    /// `dead[w]` ⇔ worker `w` crashed in an earlier round; it is never
    /// dispatched to again.
    dead: Vec<bool>,
    /// m-of-g verification level (`None` = first replica wins): each
    /// batch waits for `m` results and the round accepts the majority
    /// value — see [`crate::des::Scenario::verify_m`].
    verify_m: Option<usize>,
    /// Voting strikes per worker; a worker reaching
    /// `cfg.verify_strikes` is quarantined at the end of the round.
    /// Reset on respawn (a fresh process starts with a clean record).
    strikes: Vec<u64>,
    /// Set once any strike quarantine fired: arms graceful degradation
    /// (re-plan onto survivors) even without a fault plan installed, so
    /// a quarantine that breaks coverage degrades instead of erroring.
    quarantine_armed: bool,
    /// Fault injection armed by [`Coordinator::crash_worker_next_round`]:
    /// `(worker, fraction_of_delay)` applied to the next round only.
    pending_crash: Option<(usize, f64)>,
    /// Per-replica telemetry of the last round:
    /// `(batch, draw, speed, crash_at)` with `draw` the sampled
    /// size-scaled batch service (no time scale, no speed multiplier),
    /// `speed` the worker's multiplier, and `crash_at` the normalized
    /// time a crashing replica dies at. Consumed by
    /// [`Coordinator::take_round_observations`].
    round_times: Vec<(usize, f64, f64, Option<f64>)>,
    scratch: RoundScratch,
    /// Metrics across all jobs run by this coordinator.
    pub metrics: RunMetrics,
}

impl Coordinator {
    /// Build the full System1: batching (stage 1), assignment (stage 2),
    /// data placement, and worker spawn.
    pub fn new(cfg: SystemConfig, backend: Backend) -> anyhow::Result<Coordinator> {
        cfg.validate()?;
        let mut rng = Rng::new(cfg.seed);
        let assignment = cfg.policy.assign(cfg.n_workers, cfg.n_batches, &mut rng)?;
        let eff_b = assignment.n_batches;
        let layout = if cfg.overlapping {
            crate::batching::overlapping(cfg.n_workers, eff_b, cfg.n_workers / eff_b)?
        } else {
            crate::batching::disjoint(cfg.n_workers, eff_b)?
        };
        Self::from_parts(cfg, layout, assignment, None, backend)
    }

    /// Build a live System1 directly from a validated [`Scenario`] —
    /// the [`crate::evaluator::LiveEvaluator`] entry point. The
    /// scenario supplies structure (layout, assignment, service law,
    /// speeds, seed); `cfg` supplies the live-only knobs (time scale,
    /// dataset size, dimension, cancellation, artifacts dir).
    pub fn from_scenario(
        scn: &crate::des::Scenario,
        mut cfg: SystemConfig,
        backend: Backend,
    ) -> anyhow::Result<Coordinator> {
        cfg.n_workers = scn.n_workers();
        cfg.n_batches = scn.assignment.n_batches;
        cfg.overlapping = scn.layout.is_overlapping;
        cfg.service = scn.service.spec.clone();
        cfg.batch_model = scn.service.model;
        cfg.seed = scn.seed;
        cfg.k_of_b = scn.k_of_b.unwrap_or(0);
        cfg.verify_m = scn.verify_m.unwrap_or(0);
        Self::from_parts(
            cfg,
            scn.layout.clone(),
            scn.assignment.clone(),
            scn.worker_speeds.clone(),
            backend,
        )
    }

    fn from_parts(
        cfg: SystemConfig,
        layout: DataLayout,
        assignment: Assignment,
        speeds: Option<Vec<f64>>,
        backend: Backend,
    ) -> anyhow::Result<Coordinator> {
        cfg.validate()?;
        layout.validate()?;
        assignment.validate()?;
        if let Some(sp) = &speeds {
            anyhow::ensure!(sp.len() == cfg.n_workers, "need one speed per worker");
        }
        let rng = Rng::new(cfg.seed);
        let dataset = Arc::new(Dataset::synth_regression(
            cfg.n_samples,
            cfg.dim,
            0.05,
            cfg.seed ^ 0xDA7A,
        ));

        let (res_tx, res_rx): (Sender<ResultMsg>, Receiver<ResultMsg>) =
            std::sync::mpsc::channel();
        let service = BatchService { spec: cfg.service.clone(), model: cfg.batch_model };
        let scratch = RoundScratch::new(layout.n_units, assignment.n_batches);
        let k_of_b = match cfg.k_of_b {
            0 => None,
            k => Some(k.min(assignment.n_batches)),
        };
        let verify_m = match cfg.verify_m {
            0 | 1 => None,
            m => {
                let min_degree = (0..assignment.n_batches)
                    .map(|b| assignment.replication(b))
                    .min()
                    .unwrap_or(0);
                anyhow::ensure!(
                    m <= min_degree,
                    "verify_m = {m} exceeds the minimum replication degree {min_degree}: \
                     some batch has only {min_degree} replica(s) and can never collect \
                     {m} votes (raise replication or lower verify_m)"
                );
                Some(m)
            }
        };
        let n = cfg.n_workers;
        let mut coord = Coordinator {
            rng,
            assignment,
            layout,
            service,
            dataset,
            workers: Vec::with_capacity(n),
            results: res_rx,
            res_tx,
            backend,
            next_job: 0,
            fault: None,
            round_index: 0,
            respawn_at: vec![None; n],
            respawn_attempts: vec![0; n],
            speeds,
            k_of_b,
            dead: vec![false; n],
            verify_m,
            strikes: vec![0; n],
            quarantine_armed: false,
            pending_crash: None,
            round_times: Vec::new(),
            scratch,
            metrics: RunMetrics::new(),
            cfg,
        };
        for w in 0..n {
            let handle = coord.spawn_one(w)?;
            coord.workers.push(handle);
        }
        Ok(coord)
    }

    /// Spawn (or respawn) worker `w` against the **current** layout and
    /// assignment — the shard is rebuilt from scratch, so a degraded
    /// re-plan hands every worker its new batch.
    fn spawn_one(&self, w: usize) -> anyhow::Result<WorkerHandle> {
        let batch = self.assignment.batch_of_worker[w];
        let ranges = self.layout.sample_ranges(batch, self.cfg.n_samples);
        let shard = self.dataset.shard(&ranges);
        let artifact_dir = std::path::PathBuf::from(&self.cfg.artifacts_dir);
        match self.backend {
            Backend::Mock => spawn_worker(
                w,
                shard,
                || Ok(Box::new(crate::worker::MockCompute) as Box<dyn Compute>),
                self.res_tx.clone(),
            ),
            Backend::Pjrt => spawn_worker(
                w,
                shard,
                move || {
                    Ok(Box::new(crate::worker::PjrtCompute::new(&artifact_dir)?)
                        as Box<dyn Compute>)
                },
                self.res_tx.clone(),
            ),
        }
    }

    /// Install a compiled [`crate::fault::FaultPlan`]. Event round
    /// indices are absolute (the coordinator's round counter, 0-based
    /// from construction), so install the plan before the first round
    /// for the schedule to line up. Installing a plan also arms the
    /// self-healing machinery: per-batch deadline relaunch, worker
    /// respawn, and degraded-mode re-planning.
    pub fn install_fault_plan(&mut self, plan: &crate::fault::FaultPlan) -> anyhow::Result<()> {
        self.fault = Some(plan.compile(self.cfg.n_workers)?);
        Ok(())
    }

    /// Rounds run so far.
    pub fn round_index(&self) -> u64 {
        self.round_index
    }

    /// The dataset in use.
    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    /// The effective assignment.
    pub fn assignment(&self) -> &Assignment {
        &self.assignment
    }

    /// Number of workers still alive (not crashed).
    pub fn live_workers(&self) -> usize {
        self.dead.iter().filter(|&&d| !d).count()
    }

    /// Per-worker liveness (`true` = crashed).
    pub fn dead_workers(&self) -> &[bool] {
        &self.dead
    }

    /// Arm fault injection: worker `w` crashes during the **next** round,
    /// `fraction` of the way through its sampled straggle. It reports one
    /// final `out: None` result, its thread exits, and it is excluded
    /// from every later dispatch — the live analogue of the DES engine's
    /// replica failure, but taking the whole node down mid-round.
    pub fn crash_worker_next_round(&mut self, w: usize, fraction: f64) -> anyhow::Result<()> {
        anyhow::ensure!(w < self.cfg.n_workers, "worker {w} out of range");
        anyhow::ensure!(!self.dead[w], "worker {w} is already dead");
        anyhow::ensure!(
            fraction > 0.0 && fraction.is_finite(),
            "crash fraction must be positive and finite"
        );
        anyhow::ensure!(self.pending_crash.is_none(), "a crash is already armed");
        self.pending_crash = Some((w, fraction));
        Ok(())
    }

    /// Drain the last round's per-replica telemetry as censoring-aware
    /// observations for [`crate::control::CensoredAccumulator`]: per
    /// batch, the replica with the smallest injected wall-clock delay
    /// among those that can complete is the winner — an **exact**
    /// observation of the size-scaled batch service — and every sibling
    /// is **right-censored** at the winner's wall time converted into
    /// the sibling's own normalized units (first-completion-wins
    /// cancellation stops it there); a crashed replica is censored at
    /// the earlier of its crash and the winner. Times carry no
    /// `time_scale` or worker-speed factor, so observations from fast
    /// and slow workers estimate the same service law.
    pub fn take_round_observations(&mut self) -> Vec<crate::control::Observation> {
        use crate::control::Observation;
        let b = self.assignment.n_batches;
        // Per-batch winner among completing replicas, by wall-clock
        // delay (draw × speed); remember the winning delay.
        let mut win_delay = vec![f64::INFINITY; b];
        for &(batch, draw, speed, crash_at) in &self.round_times {
            if crash_at.is_none() && draw * speed < win_delay[batch] {
                win_delay[batch] = draw * speed;
            }
        }
        let mut obs = Vec::with_capacity(self.round_times.len());
        let mut won = vec![false; b];
        for &(batch, draw, speed, crash_at) in &self.round_times {
            let wd = win_delay[batch];
            if crash_at.is_none() && draw * speed == wd && !won[batch] {
                won[batch] = true;
                obs.push(Observation::exact(draw));
                continue;
            }
            // The winner finished at wall delay `wd`; in this replica's
            // normalized units that instant is `wd / speed` (≤ its own
            // draw, since the winner minimizes the wall delay).
            let cancel_at = if wd.is_finite() { wd / speed } else { draw };
            let cap = match crash_at {
                Some(c) => c.min(cancel_at),
                None => cancel_at,
            };
            obs.push(Observation::censored(cap));
        }
        self.round_times.clear();
        obs
    }

    /// Respawn every dead worker whose backoff expired at this round. A
    /// respawned worker starts with a clean strike record. A failed
    /// spawn (thread limit, OS pressure) leaves the worker dead and
    /// re-schedules the attempt with the usual backoff instead of
    /// aborting the run.
    fn process_respawns(&mut self, round: u64, events: &mut RoundEvents) {
        for w in 0..self.cfg.n_workers {
            if self.dead[w] && self.respawn_at[w].is_some_and(|at| round >= at) {
                self.respawn_at[w] = None;
                match self.spawn_one(w) {
                    Ok(fresh) => {
                        let old = std::mem::replace(&mut self.workers[w], fresh);
                        // The crashed thread has already exited; this
                        // just joins it and drops its stale channel.
                        old.shutdown();
                        self.dead[w] = false;
                        self.strikes[w] = 0;
                        events.respawns += 1;
                        if crate::obs::enabled() {
                            crate::obs::emit(
                                "coordinator",
                                "respawn",
                                &[("worker", w.into()), ("round", round.into())],
                            );
                        }
                    }
                    Err(e) => {
                        eprintln!("worker {w}: respawn failed ({e}); retrying with backoff");
                        let backoff = 1u64 << self.respawn_attempts[w].min(3);
                        self.respawn_at[w] = Some(round + backoff.max(1));
                        self.respawn_attempts[w] = self.respawn_attempts[w].saturating_add(1);
                    }
                }
            }
        }
    }

    /// Take worker `w` down: mark it dead and, for a transient crash,
    /// schedule its respawn with exponential backoff between attempts
    /// (`d`, `2d`, `4d`, `8d` rounds, capped at 8×).
    fn mark_dead(
        &mut self,
        w: usize,
        round: u64,
        respawn_after: Option<u64>,
        events: &mut RoundEvents,
    ) {
        self.dead[w] = true;
        events.crashes += 1;
        if crate::obs::enabled() {
            crate::obs::emit(
                "coordinator",
                "crash",
                &[("worker", w.into()), ("round", round.into())],
            );
        }
        if let Some(d) = respawn_after {
            let backoff = 1u64 << self.respawn_attempts[w].min(3);
            self.respawn_at[w] = Some(round + d.saturating_mul(backoff));
            self.respawn_attempts[w] = self.respawn_attempts[w].saturating_add(1);
        }
    }

    /// Stamp `batch_ok` for every batch holding at least one live,
    /// non-crashing replica and return the count — the round's coverage
    /// feasibility, checked **before** dispatch. (A plan-dropped task
    /// does not count against feasibility: the dropping worker is alive
    /// and the deadline relaunch recovers the batch within the round.)
    fn covered_batches(&mut self, crashing: &[Option<(f64, Option<u64>)>], gen: u32) -> usize {
        for w in 0..self.cfg.n_workers {
            if !self.dead[w] && crashing[w].is_none() {
                self.scratch.batch_ok[self.assignment.batch_of_worker[w]] = gen;
            }
        }
        self.scratch.batch_ok.iter().filter(|&&s| s == gen).count()
    }

    /// Batches a round must cover to complete.
    fn needed_batches(&self) -> usize {
        match self.k_of_b {
            Some(k) => k,
            None => self.assignment.n_batches,
        }
    }

    /// Graceful degradation: re-plan the assignment onto the surviving
    /// workers at a (possibly) reduced batch count — more replication
    /// per batch, never less — rebuild the disjoint layout and every
    /// live worker's shard, and clamp the k-of-B target.
    fn degrade_to_survivors(&mut self, events: &mut RoundEvents) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.layout.is_overlapping,
            "cannot re-plan an overlapping layout onto survivors"
        );
        let n_live = self.live_workers();
        anyhow::ensure!(n_live >= 1, "every worker is dead — nothing to re-plan onto");
        let b_new = crate::fault::degraded_batch_count(
            self.layout.n_units,
            n_live,
            self.assignment.n_batches,
        );
        self.assignment =
            crate::fault::degraded_assignment(self.cfg.n_workers, &self.dead, b_new)?;
        self.layout = crate::batching::disjoint(self.layout.n_units, b_new)?;
        self.scratch = RoundScratch::new(self.layout.n_units, b_new);
        if let Some(k) = &mut self.k_of_b {
            *k = (*k).min(b_new);
        }
        // Every live worker's shard changed under the new layout —
        // replace them all (respawn with the new batch).
        for w in 0..self.cfg.n_workers {
            if !self.dead[w] {
                let fresh = self.spawn_one(w)?;
                let old = std::mem::replace(&mut self.workers[w], fresh);
                old.shutdown();
            }
        }
        events.degradations += 1;
        if crate::obs::enabled() {
            crate::obs::emit(
                "coordinator",
                "degrade",
                &[("b_new", b_new.into()), ("live", n_live.into())],
            );
        }
        Ok(())
    }

    /// Run one job round: dispatch to every live worker, first replica
    /// per batch wins, aggregate the winners. With a fault plan
    /// installed ([`Coordinator::install_fault_plan`]) the round also
    /// runs the self-healing pipeline: respawn due workers, inject
    /// scheduled crashes / slowdowns / task drops, re-plan onto
    /// survivors when coverage becomes infeasible, and relaunch batches
    /// that miss their per-batch liveness deadline (capped exponential
    /// backoff). There is no blanket worker timeout: every injected
    /// delay is known at dispatch, so the collect loop is bounded by
    /// per-batch deadlines plus a delay-scaled whole-round liveness
    /// bound, and breaching either is a named error.
    pub fn run_round(&mut self, spec: JobSpec) -> anyhow::Result<RoundResult> {
        let job_id = self.next_job;
        self.next_job += 1;
        let round = self.round_index;
        self.round_index += 1;
        let n = self.cfg.n_workers;
        let mut events = RoundEvents::default();

        // Self-healing step 1: bring back dead workers whose respawn
        // backoff expired.
        self.process_respawns(round, &mut events);

        // Fault schedule for this round: the hand-armed single crash
        // plus any plan-scheduled crashes firing now on live workers.
        // `crashing[w] = Some((fraction_of_delay, respawn_after))`.
        let mut crashing: Vec<Option<(f64, Option<u64>)>> = vec![None; n];
        if let Some((cw, frac)) = self.pending_crash.take() {
            crashing[cw] = Some((frac, None));
        }
        if let Some(plan) = &self.fault {
            for w in 0..n {
                if let Some(c) = plan.crash_of(w) {
                    if !self.dead[w] && c.round == round {
                        crashing[w] = Some((c.fraction, c.respawn_after));
                    }
                }
            }
        }

        // Coverage feasibility under worker death, checked before any
        // dispatch: every batch (or at least k of them, under a k-of-B
        // target) must keep one replica that can complete, otherwise
        // the round can never finish. With a fault plan the answer to
        // infeasibility is graceful degradation; without one it is a
        // named error.
        let mut gen = self.scratch.begin_round();
        let ok_batches = self.covered_batches(&crashing, gen);
        if ok_batches < self.needed_batches() {
            if self.fault.is_some() || self.quarantine_armed {
                // The crashing workers are doomed either way — take
                // them down at round start so the re-plan sees the true
                // survivor set, then rebuild the assignment onto it.
                for w in 0..n {
                    if !self.dead[w] {
                        if let Some((_, respawn_after)) = crashing[w].take() {
                            self.mark_dead(w, round, respawn_after, &mut events);
                        }
                    }
                }
                self.degrade_to_survivors(&mut events)?;
                gen = self.scratch.begin_round();
                let ok = self.covered_batches(&crashing, gen);
                anyhow::ensure!(
                    ok >= self.needed_batches(),
                    "degraded re-plan still infeasible: {ok} of {} batches have a live replica",
                    self.assignment.n_batches
                );
            } else {
                match self.k_of_b {
                    Some(k) => anyhow::bail!(
                        "only {ok_batches} batches have a live replica (k-of-B target {k})"
                    ),
                    None => anyhow::bail!(
                        "{} of {} batches lost every live replica — cannot cover the dataset",
                        self.assignment.n_batches - ok_batches,
                        self.assignment.n_batches
                    ),
                }
            }
        }
        let s_units = self.layout.batch_units() as u64;

        // Dispatch: one replica per live worker with a sampled straggle
        // (scaled by any plan slowdown), skipping plan-dropped tasks.
        let timer = Timer::start();
        let mut max_injected_winner = 0f64;
        let mut dispatched = 0usize;
        self.round_times.clear();
        for w in 0..n {
            if self.dead[w] {
                continue;
            }
            if let Some(plan) = &self.fault {
                if plan.drops_task(w, round) {
                    // The worker never starts this round's task; the
                    // per-batch deadline relaunch recovers the batch.
                    events.dropped += 1;
                    if crate::obs::enabled() {
                        crate::obs::emit(
                            "fault",
                            "task_drop",
                            &[("worker", w.into()), ("round", round.into())],
                        );
                    }
                    continue;
                }
            }
            let batch = self.assignment.batch_of_worker[w];
            let speed = self.speeds.as_ref().map_or(1.0, |sp| sp[w]);
            let slow = self.fault.as_ref().map_or(1.0, |p| p.slow_factor(w, round));
            if slow != 1.0 && crate::obs::enabled() {
                crate::obs::emit(
                    "fault",
                    "slowdown",
                    &[("worker", w.into()), ("round", round.into()), ("factor", slow.into())],
                );
            }
            // The effective draw folds the slowdown in, so telemetry
            // (and the control loop fed by it) observes the drifted law.
            let draw = self.service.sample_batch(s_units, &mut self.rng) * slow;
            let delay = self.cfg.time_scale * draw * speed;
            let crash_after_s = crashing[w].map(|(frac, _)| frac * delay);
            if crash_after_s.is_none() && delay > self.scratch.batch_max_delay[batch] {
                self.scratch.batch_max_delay[batch] = delay;
            }
            // Telemetry: the effective draw, this worker's speed, and
            // (for a crashing replica) the normalized time it dies at.
            let crash_at = crashing[w].map(|(frac, _)| frac * draw);
            self.round_times.push((batch, draw, speed, crash_at));
            // Silent-corruption injection: a pure function of the plan
            // (no RNG consumed), so injected runs replay byte-identical
            // service draws.
            let corrupt = self.fault.as_ref().is_some_and(|p| p.corrupts_result(w, round));
            let cancel = self.scratch.cancels[batch].clone();
            let send = self.workers[w].tx.send(TaskMsg {
                job_id,
                batch_id: batch,
                spec: spec.clone(),
                delay_s: delay,
                cancel,
                crash_after_s,
                corrupt,
            });
            if send.is_err() {
                // The worker thread died outside any plan (panic, spawn
                // failure): treat it as a crash and keep the round
                // alive — respawn machinery brings it back, and if its
                // batch cannot recover the liveness bound names the
                // stall instead of aborting here.
                eprintln!("worker {w}: task channel closed — marking dead");
                self.round_times.pop();
                self.mark_dead(w, round, Some(1), &mut events);
                continue;
            }
            if corrupt {
                events.corrupted += 1;
            }
            self.scratch.batch_pending[batch] += 1;
            dispatched += 1;
        }
        // One clock read: wall time spent sampling + dispatching the
        // whole round (the dispatch leg of OverheadStats).
        let dispatch_s = timer.secs();

        // Liveness bounds. The whole round is bounded by the slowest
        // completable replica (scaled by the relaunch factor, plus
        // real-compute grace); in fault mode each batch additionally
        // carries a speculative relaunch deadline — a batch with no
        // completable replica dispatched (all dropped) gets an
        // immediate one.
        let b_count = self.assignment.n_batches;
        let fault_mode = self.fault.is_some();
        let mut overall_deadline = dispatch_s + LIVENESS_GRACE_S;
        for b in 0..b_count {
            let base = self.cfg.relaunch_factor * self.scratch.batch_max_delay[b];
            overall_deadline = overall_deadline.max(dispatch_s + base + LIVENESS_GRACE_S);
            if fault_mode {
                self.scratch.batch_deadline[b] = dispatch_s + base + RELAUNCH_FLOOR_S;
            }
        }

        // Collect. Completion is declared at coverage (all data units
        // covered by winning batches) or, under a k-of-B target, at the
        // k-th finished batch; the round ends for bookkeeping when
        // every dispatched (or relaunched) task has reported (cancelled
        // workers report quickly, and a crashing worker reports its
        // death notice).
        let n_units = self.layout.n_units;
        let mut units_left = n_units;
        let mut batches_won = 0usize;
        let mut reported = 0usize;
        let mut redundant = 0u64;
        let mut cancelled = 0u64;
        let mut completion_wall = None;
        let mut agg: Option<RoundOutput> = None;

        while reported < dispatched {
            // The nearest actionable instant: an unwon batch's relaunch
            // deadline (fault mode) or the whole-round liveness bound.
            let mut next_deadline = overall_deadline;
            if fault_mode && completion_wall.is_none() {
                for b in 0..b_count {
                    if self.scratch.batch_won[b] != gen {
                        next_deadline = next_deadline.min(self.scratch.batch_deadline[b]);
                    }
                }
            }
            let wait = (next_deadline - timer.secs()).max(1e-3);
            let msg = match self.results.recv_timeout(std::time::Duration::from_secs_f64(wait)) {
                Ok(msg) => msg,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("worker result channel disconnected mid-round")
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    let now = timer.secs();
                    if fault_mode && completion_wall.is_none() {
                        // Speculative relaunch of every batch past its
                        // deadline, with capped exponential backoff.
                        for b in 0..b_count {
                            if self.scratch.batch_won[b] == gen
                                || now < self.scratch.batch_deadline[b]
                            {
                                continue;
                            }
                            // Workers hold batch-specific shards, so the
                            // relaunch targets the batch's first live,
                            // non-crashing replica.
                            let target = self.assignment.workers_of_batch[b]
                                .iter()
                                .copied()
                                .find(|&w| !self.dead[w] && crashing[w].is_none());
                            let Some(w) = target else {
                                // No live replica to relaunch on: under
                                // a k-of-B target the round can finish
                                // without this batch; otherwise the
                                // liveness bound below names the stall.
                                self.scratch.batch_deadline[b] = f64::INFINITY;
                                continue;
                            };
                            anyhow::ensure!(
                                (self.scratch.batch_attempts[b] as u64)
                                    < self.cfg.max_relaunches,
                                "batch {b} kept missing its liveness deadline — giving up \
                                 after {} relaunches",
                                self.cfg.max_relaunches
                            );
                            let speed = self.speeds.as_ref().map_or(1.0, |sp| sp[w]);
                            let slow =
                                self.fault.as_ref().map_or(1.0, |p| p.slow_factor(w, round));
                            // Fresh draw; the drop coin is NOT re-flipped
                            // — the relaunch is the recovery path.
                            let draw = self.service.sample_batch(s_units, &mut self.rng) * slow;
                            let delay = self.cfg.time_scale * draw * speed;
                            self.round_times.push((b, draw, speed, None));
                            let corrupt = self
                                .fault
                                .as_ref()
                                .is_some_and(|p| p.corrupts_result(w, round));
                            let cancel = self.scratch.cancels[b].clone();
                            let send = self.workers[w].tx.send(TaskMsg {
                                job_id,
                                batch_id: b,
                                spec: spec.clone(),
                                delay_s: delay,
                                cancel,
                                crash_after_s: None,
                                corrupt,
                            });
                            if send.is_err() {
                                // Same hardening as dispatch: a dead
                                // relaunch target becomes a crash, not
                                // an abort — another replica or the
                                // liveness bound takes over.
                                eprintln!(
                                    "worker {w}: task channel closed — marking dead"
                                );
                                self.round_times.pop();
                                self.mark_dead(w, round, Some(1), &mut events);
                                continue;
                            }
                            if corrupt {
                                events.corrupted += 1;
                            }
                            self.scratch.batch_pending[b] += 1;
                            dispatched += 1;
                            events.relaunches += 1;
                            if crate::obs::enabled() {
                                crate::obs::emit(
                                    "coordinator",
                                    "relaunch",
                                    &[
                                        ("round", round.into()),
                                        ("batch", b.into()),
                                        ("worker", w.into()),
                                    ],
                                );
                            }
                            if delay > self.scratch.batch_max_delay[b] {
                                self.scratch.batch_max_delay[b] = delay;
                            }
                            // Back off: double the timeout per attempt,
                            // capped at 16×.
                            self.scratch.batch_attempts[b] += 1;
                            let backoff =
                                f64::from(1u32 << self.scratch.batch_attempts[b].min(4));
                            let timeout = (self.cfg.relaunch_factor
                                * self.scratch.batch_max_delay[b]
                                + RELAUNCH_FLOOR_S)
                                * backoff;
                            self.scratch.batch_deadline[b] = now + timeout;
                            overall_deadline =
                                overall_deadline.max(now + timeout + LIVENESS_GRACE_S);
                        }
                    }
                    if now >= overall_deadline {
                        if crate::obs::enabled() {
                            crate::obs::emit(
                                "coordinator",
                                "timeout",
                                &[
                                    ("round", round.into()),
                                    ("unreported", (dispatched - reported).into()),
                                ],
                            );
                        }
                        anyhow::bail!(
                            "round {round} missed its liveness deadline \
                             ({overall_deadline:.1}s): {} of {dispatched} tasks unreported",
                            dispatched - reported
                        );
                    }
                    continue;
                }
            };
            if msg.job_id != job_id {
                // Stale result from a previous (already-completed) round.
                continue;
            }
            reported += 1;
            let batch = msg.batch_id;
            self.scratch.batch_pending[batch] =
                self.scratch.batch_pending[batch].saturating_sub(1);
            // The value this arrival decides the batch with, if any.
            let mut accepted: Option<(JobOut, f64)> = None;
            match msg.out {
                None => cancelled += 1,
                Some(out) => {
                    if self.scratch.batch_won[batch] == gen || completion_wall.is_some() {
                        // The batch is already decided, or the whole job
                        // completed (k-of-B target hit, or coverage
                        // reached in an overlapping layout): a straggler
                        // that beat its cancel is pure redundancy —
                        // don't aggregate it or let it move the
                        // completion statistics.
                        redundant += 1;
                    } else if self.verify_m.is_some() {
                        self.scratch.batch_votes[batch].push((
                            msg.worker_id,
                            out,
                            msg.injected_s,
                        ));
                    } else {
                        accepted = Some((out, msg.injected_s));
                    }
                }
            }
            // m-of-g vote: decide the batch at the first arrival where
            // some agreement group has ≥ 2 members and ≥ m results are
            // in, or when no more replicas can report (exhausted).
            if let Some(m) = self.verify_m {
                if accepted.is_none()
                    && self.scratch.batch_won[batch] != gen
                    && completion_wall.is_none()
                    && !self.scratch.batch_votes[batch].is_empty()
                {
                    let votes = &self.scratch.batch_votes[batch];
                    let (rep, size) = vote_winner(votes);
                    let exhausted = self.scratch.batch_pending[batch] == 0;
                    if (votes.len() >= m && size >= 2) || exhausted {
                        let injected = votes.iter().fold(0f64, |a, v| a.max(v.2));
                        if size >= 2 {
                            // Majority found: accept its value; flag
                            // every collected replica that disagreed.
                            for j in 0..votes.len() {
                                if !job_out_agree(&votes[rep].1, &votes[j].1) {
                                    events.flagged += 1;
                                    self.strikes[votes[j].0] += 1;
                                }
                            }
                            accepted = Some((votes[rep].1.clone(), injected));
                        } else {
                            // Exhausted without a majority. Two or more
                            // disagreeing values = corruption detected
                            // but unattributable: accept the earliest
                            // value and count a degradation, flagging
                            // nobody. A lone vote (quorum short through
                            // crashes or cancels, nothing to compare
                            // against) is accepted best-effort.
                            if votes.len() >= 2 {
                                events.degradations += 1;
                            }
                            accepted = Some((votes[0].1.clone(), injected));
                        }
                    }
                }
            }
            if let Some((out, injected)) = accepted {
                self.scratch.batch_won[batch] = gen;
                batches_won += 1;
                if self.cfg.cancellation {
                    self.scratch.cancels[batch].store(true, Ordering::Relaxed);
                }
                // Aggregation unit: fold the accepted value in.
                agg = Some(match (agg.take(), out) {
                    (None, JobOut::Grad(g)) => RoundOutput::Grad(g),
                    (None, JobOut::MapSum(v)) => RoundOutput::MapSum(v),
                    (Some(RoundOutput::Grad(mut acc)), JobOut::Grad(g)) => {
                        for (a, x) in acc.grad.iter_mut().zip(&g.grad) {
                            *a += x;
                        }
                        acc.loss += g.loss;
                        RoundOutput::Grad(acc)
                    }
                    (Some(RoundOutput::MapSum(acc)), JobOut::MapSum(v)) => {
                        RoundOutput::MapSum(acc + v)
                    }
                    _ => anyhow::bail!("mixed job outputs in one round"),
                });
                max_injected_winner = max_injected_winner.max(injected);
                for &u in &self.layout.units_of_batch[batch] {
                    if self.scratch.unit_covered[u] != gen {
                        self.scratch.unit_covered[u] = gen;
                        units_left -= 1;
                    }
                }
                let complete = match self.k_of_b {
                    Some(k) => batches_won >= k,
                    None => units_left == 0,
                };
                if complete && completion_wall.is_none() {
                    completion_wall = Some(timer.secs());
                    if self.cfg.cancellation {
                        // Remaining batches — overlapping stragglers
                        // past coverage, or batches beyond the k-of-B
                        // target — are moot once the job is complete.
                        for c in &self.scratch.cancels {
                            c.store(true, Ordering::Relaxed);
                        }
                    }
                }
            }
        }

        // Crashed workers' threads have exited; mark them dead and
        // schedule any transient respawns.
        for w in 0..n {
            if !self.dead[w] {
                if let Some((_, respawn_after)) = crashing[w] {
                    self.mark_dead(w, round, respawn_after, &mut events);
                }
            }
        }

        // Strike-budget quarantine, also at end of round (the flagged
        // results were already rejected by the vote): exclude the
        // worker from dispatch and hand it to the respawn machinery
        // with the crash backoff; its strike record resets on respawn.
        // A worker that crashed this same round is already dead.
        if self.verify_m.is_some() {
            let limit = self.cfg.verify_strikes.max(1);
            for w in 0..n {
                if !self.dead[w] && self.strikes[w] >= limit {
                    self.dead[w] = true;
                    self.quarantine_armed = true;
                    events.quarantined += 1;
                    if crate::obs::enabled() {
                        crate::obs::emit(
                            "coordinator",
                            "quarantine",
                            &[("round", round.into()), ("worker", w.into())],
                        );
                    }
                    let backoff = 1u64 << self.respawn_attempts[w].min(3);
                    self.respawn_at[w] = Some(
                        round
                            + crate::fault::QUARANTINE_RESPAWN_ROUNDS
                                .saturating_mul(backoff),
                    );
                    self.respawn_attempts[w] = self.respawn_attempts[w].saturating_add(1);
                }
            }
        }

        let completion = completion_wall.ok_or_else(|| {
            anyhow::anyhow!("round ended without coverage (all replicas cancelled?)")
        })?;
        self.metrics.push(JobRecord {
            job_id,
            completion_s: completion,
            injected_s: max_injected_winner,
            dispatch_s,
            dispatched: dispatched as u64,
            redundant,
            cancelled,
        });
        self.metrics.note_fault_events(&events);
        {
            use crate::obs::{bump, Counter};
            bump(Counter::LiveRounds, 1);
            bump(Counter::LiveCrashes, events.crashes);
            bump(Counter::LiveRespawns, events.respawns);
            bump(Counter::LiveRelaunches, events.relaunches);
            bump(Counter::LiveDegradations, events.degradations);
            bump(Counter::LiveDropped, events.dropped);
            bump(Counter::LiveCorrupted, events.corrupted);
            bump(Counter::LiveFlagged, events.flagged);
            bump(Counter::LiveQuarantined, events.quarantined);
        }
        if crate::obs::enabled() {
            crate::obs::emit(
                "coordinator",
                "round",
                &[
                    ("round", round.into()),
                    ("wall_s", completion.into()),
                    ("injected_s", max_injected_winner.into()),
                    ("dispatch_s", dispatch_s.into()),
                    ("dispatched", dispatched.into()),
                    ("redundant", redundant.into()),
                    ("cancelled", cancelled.into()),
                    ("relaunches", events.relaunches.into()),
                    ("crashes", events.crashes.into()),
                    ("quarantined", events.quarantined.into()),
                ],
            );
        }
        let output = agg.ok_or_else(|| anyhow::anyhow!("no results aggregated"))?;
        Ok(RoundResult { output, events })
    }

    /// Run distributed SGD for `steps` rounds with learning rate `lr`.
    pub fn run_training(&mut self, steps: u64, lr: f64) -> anyhow::Result<TrainingReport> {
        // Note on semantics: replication here provides *straggler
        // tolerance for exact computation* — each batch's winning
        // replica computes the same gradient sum over a disjoint
        // partition, so every step is exactly full-batch GD, independent
        // of which replicas win. (With overlapping layouts the covered
        // multiset can overcount units; the paper's System1 aggregates
        // batch results, so overlapping batches are only used with
        // coverage-aware jobs — for gradients we restrict to disjoint.)
        anyhow::ensure!(
            !self.layout.is_overlapping,
            "gradient training requires a disjoint layout (exact aggregation)"
        );
        let dim = self.cfg.dim;
        let n_samples = self.cfg.n_samples as f64;
        let mut w = vec![0f32; dim];
        let mut loss_curve = Vec::with_capacity(steps as usize);
        for _ in 0..steps {
            let spec = JobSpec::Grad { w: Arc::new(w.clone()) };
            let res = self.run_round(spec)?;
            if res.events.any() {
                // Surface fault/recovery activity inline so chaos runs
                // are debuggable without reading the CHAOS artifact.
                let e = res.events;
                println!(
                    "  [fault] round {}: crashes={} respawns={} relaunches={} \
                     degradations={} dropped={} corrupted={} flagged={} \
                     quarantined={} live={}/{}",
                    self.round_index - 1,
                    e.crashes,
                    e.respawns,
                    e.relaunches,
                    e.degradations,
                    e.dropped,
                    e.corrupted,
                    e.flagged,
                    e.quarantined,
                    self.live_workers(),
                    self.cfg.n_workers
                );
            }
            match res.output {
                RoundOutput::Grad(out) => {
                    for (wi, gi) in w.iter_mut().zip(&out.grad) {
                        *wi -= (lr * (*gi as f64) / n_samples) as f32;
                    }
                    loss_curve.push(out.loss as f64 / n_samples);
                }
                RoundOutput::MapSum(_) => anyhow::bail!("unexpected round result"),
            }
        }
        let dist: f64 = w
            .iter()
            .zip(&self.dataset.w_star)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        Ok(TrainingReport {
            loss_curve,
            final_w: w,
            dist_to_w_star: dist,
            metrics: self.metrics.clone(),
        })
    }

    /// Run one distributed map-sum evaluation.
    pub fn run_mapsum(&mut self, a: Vec<f32>, b: Vec<f32>) -> anyhow::Result<f32> {
        anyhow::ensure!(
            !self.layout.is_overlapping,
            "map-sum aggregation requires a disjoint layout"
        );
        let spec = JobSpec::MapSum { a: Arc::new(a), b: Arc::new(b) };
        match self.run_round(spec)?.output {
            RoundOutput::MapSum(v) => Ok(v),
            RoundOutput::Grad(_) => anyhow::bail!("unexpected round result"),
        }
    }

    /// Shut down all workers.
    pub fn shutdown(self) {
        for h in self.workers {
            h.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::Policy;
    use crate::dist::ServiceSpec;

    fn test_cfg(n: usize, b: usize) -> SystemConfig {
        SystemConfig {
            n_workers: n,
            n_batches: b,
            policy: Policy::BalancedDisjoint,
            service: ServiceSpec::shifted_exp(20.0, 0.05),
            time_scale: 0.02,
            n_samples: 64,
            dim: 4,
            seed: 11,
            ..SystemConfig::default()
        }
    }

    #[test]
    fn mock_training_converges() {
        let mut c = Coordinator::new(test_cfg(4, 2), Backend::Mock).unwrap();
        let report = c.run_training(60, 0.5).unwrap();
        c.shutdown();
        assert_eq!(report.loss_curve.len(), 60);
        assert!(
            report.loss_curve[59] < report.loss_curve[0] / 10.0,
            "loss did not drop: {:?}",
            &report.loss_curve[..3]
        );
        assert!(report.dist_to_w_star < 0.2, "dist {}", report.dist_to_w_star);
    }

    #[test]
    fn aggregation_is_exact_regardless_of_winners() {
        // Replication changes *who* computes, not *what* is computed:
        // the aggregated gradient must equal the mock oracle on the
        // whole dataset, for any B.
        for b in [1usize, 2, 4] {
            let mut c = Coordinator::new(test_cfg(4, b), Backend::Mock).unwrap();
            let w = vec![0.25f32, -0.5, 1.0, 0.0];
            let spec = JobSpec::Grad { w: Arc::new(w.clone()) };
            let out = match c.run_round(spec).unwrap().output {
                RoundOutput::Grad(g) => g,
                _ => panic!(),
            };
            // Oracle: single shard over everything.
            let full = c.dataset().shard(&[(0, 64)]);
            let mut oracle = crate::worker::MockCompute;
            let expect = match oracle
                .run(&full, &JobSpec::Grad { w: Arc::new(w) })
                .unwrap()
            {
                JobOut::Grad(g) => g,
                _ => panic!(),
            };
            c.shutdown();
            for (a, e) in out.grad.iter().zip(&expect.grad) {
                assert!((a - e).abs() < 1e-2 * e.abs().max(1.0), "B={b}: {a} vs {e}");
            }
            assert!((out.loss - expect.loss).abs() < 1e-2 * expect.loss.max(1.0));
        }
    }

    #[test]
    fn mapsum_round_matches_oracle() {
        let mut c = Coordinator::new(test_cfg(4, 4), Backend::Mock).unwrap();
        let a = vec![0.1f32; 4];
        let b = vec![0.2f32; 4];
        let got = c.run_mapsum(a.clone(), b.clone()).unwrap();
        let full = c.dataset().shard(&[(0, 64)]);
        let mut oracle = crate::worker::MockCompute;
        let expect = match oracle
            .run(&full, &JobSpec::MapSum { a: Arc::new(a), b: Arc::new(b) })
            .unwrap()
        {
            JobOut::MapSum(v) => v,
            _ => panic!(),
        };
        c.shutdown();
        assert!((got - expect).abs() < 1e-3, "{got} vs {expect}");
    }

    #[test]
    fn metrics_account_replicas() {
        // B=1, N=4: one batch, 4 replicas — exactly one winner; the
        // other three are cancelled or redundant.
        let mut c = Coordinator::new(test_cfg(4, 1), Backend::Mock).unwrap();
        let spec = JobSpec::Grad { w: Arc::new(vec![0.0; 4]) };
        c.run_round(spec).unwrap();
        let recs = c.metrics.records().to_vec();
        c.shutdown();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].dispatched, 4);
        assert_eq!(recs[0].redundant + recs[0].cancelled, 3);
    }

    #[test]
    fn k_of_b_round_completes_at_kth_batch() {
        // 8 workers, 4 batches, k = 2: exactly two batch winners are
        // aggregated per round (the other six replicas are cancelled or
        // redundant), and the injected completion sits well below the
        // full-completion run of the same config.
        let rounds = 20;
        let run = |k: usize| -> (f64, Vec<crate::metrics::JobRecord>) {
            let mut cfg = test_cfg(8, 4);
            cfg.k_of_b = k;
            let mut c = Coordinator::new(cfg, Backend::Mock).unwrap();
            for _ in 0..rounds {
                c.run_round(JobSpec::Grad { w: Arc::new(vec![0.0; 4]) }).unwrap();
            }
            let recs = c.metrics.records().to_vec();
            let mean = c.metrics.mean_injected();
            c.shutdown();
            (mean, recs)
        };
        let (mean_k, recs_k) = run(2);
        for r in &recs_k {
            assert_eq!(r.dispatched, 8);
            assert_eq!(
                r.redundant + r.cancelled,
                6,
                "k=2 of 4 must aggregate exactly two batch winners: {r:?}"
            );
        }
        let (mean_full, _) = run(0);
        assert!(
            mean_k < mean_full,
            "k-of-B completion {mean_k} must beat full completion {mean_full}"
        );
    }

    #[test]
    fn full_parallelism_has_no_redundancy() {
        let mut cfg = test_cfg(4, 4);
        cfg.policy = Policy::FullParallelism;
        let mut c = Coordinator::new(cfg, Backend::Mock).unwrap();
        let spec = JobSpec::Grad { w: Arc::new(vec![0.0; 4]) };
        c.run_round(spec).unwrap();
        let recs = c.metrics.records().to_vec();
        c.shutdown();
        assert_eq!(recs[0].redundant, 0);
        assert_eq!(recs[0].cancelled, 0);
    }

    #[test]
    fn crash_mid_round_survivors_complete_and_worker_stays_dead() {
        // N=4, B=2 (g=2): crashing one worker leaves its batch one live
        // replica, so the crash round and every later round must still
        // aggregate the exact full-batch gradient.
        let mut c = Coordinator::new(test_cfg(4, 2), Backend::Mock).unwrap();
        let w = vec![0.25f32, -0.5, 1.0, 0.0];
        let oracle = {
            let full = c.dataset().shard(&[(0, 64)]);
            let mut m = crate::worker::MockCompute;
            match m.run(&full, &JobSpec::Grad { w: Arc::new(w.clone()) }).unwrap() {
                JobOut::Grad(g) => g,
                _ => panic!(),
            }
        };
        let check = |got: RoundResult| {
            let g = match got.output {
                RoundOutput::Grad(g) => g,
                _ => panic!(),
            };
            for (a, e) in g.grad.iter().zip(&oracle.grad) {
                assert!((a - e).abs() < 1e-2 * e.abs().max(1.0), "{a} vs {e}");
            }
        };
        c.crash_worker_next_round(0, 0.5).unwrap();
        check(c.run_round(JobSpec::Grad { w: Arc::new(w.clone()) }).unwrap());
        assert_eq!(c.live_workers(), 3);
        assert!(c.dead_workers()[0]);
        // Post-crash rounds dispatch only to survivors.
        check(c.run_round(JobSpec::Grad { w: Arc::new(w.clone()) }).unwrap());
        let recs = c.metrics.records().to_vec();
        c.shutdown();
        assert_eq!(recs[0].dispatched, 4);
        assert_eq!(recs[1].dispatched, 3);
    }

    #[test]
    fn crash_of_sole_replica_fails_fast() {
        // g=1: the crashed worker was its batch's only replica — the
        // round can never cover the dataset, and the coordinator must
        // say so instead of hanging on results that will never come.
        let mut c = Coordinator::new(test_cfg(2, 2), Backend::Mock).unwrap();
        c.crash_worker_next_round(1, 0.5).unwrap();
        let err = c.run_round(JobSpec::Grad { w: Arc::new(vec![0.0; 4]) }).unwrap_err();
        assert!(err.to_string().contains("lost every live replica"), "{err}");
        c.shutdown();
    }

    #[test]
    fn round_telemetry_recovers_service_law() {
        // The closed loop's input: per-replica (winner exact, sibling
        // censored) observations drained after each round must let the
        // censored MLE recover the size-scaled service law. With
        // Exp(mu) service and s units per batch, draws are s·Exp(mu) =
        // Exp(mu/s).
        use crate::control::{CensoredAccumulator, FitKind};
        let mut cfg = test_cfg(4, 2);
        cfg.service = ServiceSpec::exp(20.0);
        cfg.time_scale = 1e-3;
        let mut c = Coordinator::new(cfg, Backend::Mock).unwrap();
        let s = c.layout.batch_units() as f64;
        let mut acc = CensoredAccumulator::new();
        for _ in 0..300 {
            c.run_round(JobSpec::Grad { w: Arc::new(vec![0.0; 4]) }).unwrap();
            let obs = c.take_round_observations();
            assert_eq!(obs.len(), 4, "one observation per dispatched replica");
            assert_eq!(obs.iter().filter(|o| o.exact).count(), 2, "one winner per batch");
            for o in obs {
                acc.push(o);
            }
        }
        c.shutdown();
        let fit = acc.fit(FitKind::Exp, 1.96).expect("fit");
        let expect = 20.0 / s;
        let rel = (fit.mu - expect).abs() / expect;
        assert!(rel < 0.1, "mu {} vs {expect} (rel {rel:.3})", fit.mu);
    }

    #[test]
    fn crash_arming_rejects_bad_targets() {
        // Named errors for out-of-range, dead, malformed, and
        // double-armed crash requests — and a crash of an already-dead
        // worker must not double-decrement `live_workers`.
        let mut c = Coordinator::new(test_cfg(4, 2), Backend::Mock).unwrap();
        let err = c.crash_worker_next_round(9, 0.5).unwrap_err();
        assert!(err.to_string().contains("worker 9 out of range"), "{err}");
        let err = c.crash_worker_next_round(0, 0.0).unwrap_err();
        assert!(err.to_string().contains("crash fraction must be positive"), "{err}");
        let err = c.crash_worker_next_round(0, f64::INFINITY).unwrap_err();
        assert!(err.to_string().contains("crash fraction must be positive"), "{err}");
        // Kill worker 0 for real; re-arming it must name the corpse.
        c.crash_worker_next_round(0, 0.5).unwrap();
        c.run_round(JobSpec::Grad { w: Arc::new(vec![0.0; 4]) }).unwrap();
        assert_eq!(c.live_workers(), 3);
        let err = c.crash_worker_next_round(0, 0.5).unwrap_err();
        assert!(err.to_string().contains("worker 0 is already dead"), "{err}");
        assert_eq!(c.live_workers(), 3, "dead worker must not decrement twice");
        // Two armings before the round runs is also an error.
        c.crash_worker_next_round(1, 0.5).unwrap();
        let err = c.crash_worker_next_round(2, 0.5).unwrap_err();
        assert!(err.to_string().contains("a crash is already armed"), "{err}");
        c.shutdown();
    }

    #[test]
    fn transient_crash_respawns_on_schedule() {
        // FaultPlan: worker 0 dies half-way through round 1 and comes
        // back `respawn_after = 2` rounds later (start of round 3). The
        // per-round event counters and `live_workers` must track it.
        use crate::fault::{FaultEvent, FaultPlan};
        let mut c = Coordinator::new(test_cfg(4, 2), Backend::Mock).unwrap();
        let plan = FaultPlan {
            name: "t".into(),
            seed: 7,
            events: vec![(
                0,
                FaultEvent::TransientCrash { round: 1, fraction: 0.5, respawn_after: 2 },
            )],
        };
        c.install_fault_plan(&plan).unwrap();
        let w = Arc::new(vec![0.0f32; 4]);
        let r0 = c.run_round(JobSpec::Grad { w: w.clone() }).unwrap();
        assert_eq!((r0.events.crashes, r0.events.respawns), (0, 0));
        let r1 = c.run_round(JobSpec::Grad { w: w.clone() }).unwrap();
        assert_eq!(r1.events.crashes, 1);
        assert_eq!(c.live_workers(), 3);
        let r2 = c.run_round(JobSpec::Grad { w: w.clone() }).unwrap();
        assert_eq!(r2.events.respawns, 0, "still down one round later");
        assert_eq!(c.live_workers(), 3);
        let r3 = c.run_round(JobSpec::Grad { w: w.clone() }).unwrap();
        assert_eq!(r3.events.respawns, 1, "back at crash round + respawn_after");
        assert_eq!(c.live_workers(), 4);
        let totals = c.metrics.fault_totals();
        c.shutdown();
        assert_eq!((totals.crashes, totals.respawns), (1, 1));
    }

    #[test]
    fn verify_m_exceeding_replication_is_a_named_refusal() {
        // g = 1: no batch can ever collect two votes — construction
        // must refuse, naming the offending knob and the degree.
        let mut cfg = test_cfg(4, 4);
        cfg.verify_m = 2;
        let err = match Coordinator::new(cfg, Backend::Mock) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("verify_m = 2 over g = 1 must be refused"),
        };
        assert!(err.contains("verify_m"), "{err}");
        assert!(err.contains("minimum replication degree 1"), "{err}");
    }

    #[test]
    fn corrupt_worker_is_flagged_quarantined_and_respawns_clean() {
        // N=6, B=2 (g=3), verify_m=2, worker 0 corrupt from round 1
        // with probability 1. Worker 0 is given a large speed advantage
        // so its (corrupt) result always arrives first and is always in
        // the collected votes when the two honest replicas decide the
        // batch — making the flag schedule deterministic. Strike budget
        // 2 ⇒ quarantine at the end of round 2, respawn (with a clean
        // strike record) at round 4, re-quarantine at round 5 with the
        // doubled backoff.
        use crate::dist::BatchService;
        use crate::fault::{FaultEvent, FaultPlan};
        let svc = BatchService::paper(ServiceSpec::shifted_exp(20.0, 0.05));
        let scn = crate::des::Scenario::paper_balanced(6, 2, svc)
            .unwrap()
            .with_verify_m(2)
            .unwrap()
            .with_speeds(vec![0.05, 1.0, 1.0, 1.0, 1.0, 1.0])
            .unwrap()
            .with_seed(11);
        let mut cfg = test_cfg(6, 2);
        // Wide margin between the sped-up corrupt replica (~0.4 ms) and
        // the honest arrivals (≥ 7.5 ms): the flag schedule stays
        // deterministic under scheduler noise.
        cfg.time_scale = 0.05;
        let mut c = Coordinator::from_scenario(&scn, cfg, Backend::Mock).unwrap();
        let plan = FaultPlan {
            name: "c".into(),
            seed: 5,
            events: vec![(0, FaultEvent::Corruption { from_round: 1, prob: 1.0 })],
        };
        c.install_fault_plan(&plan).unwrap();

        // The vote must reject the corrupt value: every round's
        // aggregate stays the exact full-batch gradient.
        let w = vec![0.25f32, -0.5, 1.0, 0.0];
        let oracle = {
            let full = c.dataset().shard(&[(0, c.cfg.n_samples)]);
            let mut m = crate::worker::MockCompute;
            match m.run(&full, &JobSpec::Grad { w: Arc::new(w.clone()) }).unwrap() {
                JobOut::Grad(g) => g,
                _ => panic!(),
            }
        };
        let mut run = |c: &mut Coordinator| -> RoundEvents {
            let res = c.run_round(JobSpec::Grad { w: Arc::new(w.clone()) }).unwrap();
            let g = match res.output {
                RoundOutput::Grad(g) => g,
                _ => panic!(),
            };
            for (a, e) in g.grad.iter().zip(&oracle.grad) {
                assert!((a - e).abs() < 1e-2 * e.abs().max(1.0), "{a} vs {e}");
            }
            res.events
        };

        let r0 = run(&mut c);
        assert_eq!((r0.corrupted, r0.flagged, r0.quarantined), (0, 0, 0));
        let r1 = run(&mut c);
        assert_eq!((r1.corrupted, r1.flagged, r1.quarantined), (1, 1, 0));
        assert_eq!(c.live_workers(), 6);
        let r2 = run(&mut c);
        assert_eq!((r2.corrupted, r2.flagged, r2.quarantined), (1, 1, 1));
        assert_eq!(c.live_workers(), 5, "strike budget hit: worker 0 quarantined");
        // Quarantined ⇒ excluded from dispatch: with prob = 1 any
        // dispatch of worker 0 would count as corrupted.
        let r3 = run(&mut c);
        assert_eq!((r3.corrupted, r3.respawns), (0, 0));
        assert_eq!(c.live_workers(), 5);
        // Respawn at quarantine round + QUARANTINE_RESPAWN_ROUNDS, with
        // a clean strike record: one fresh flag is not enough to
        // re-quarantine.
        let r4 = run(&mut c);
        assert_eq!((r4.respawns, r4.corrupted, r4.flagged, r4.quarantined), (1, 1, 1, 0));
        assert_eq!(c.live_workers(), 6);
        let r5 = run(&mut c);
        assert_eq!((r5.flagged, r5.quarantined), (1, 1));
        assert_eq!(c.live_workers(), 5);
        // Doubled backoff: still down two rounds later.
        let r6 = run(&mut c);
        assert_eq!(r6.respawns, 0);
        assert_eq!(c.live_workers(), 5);
        let totals = c.metrics.fault_totals();
        c.shutdown();
        assert_eq!(totals.corrupted, 4);
        assert_eq!(totals.flagged, 4);
        assert_eq!(totals.quarantined, 2);
    }

    #[test]
    fn all_corrupt_batch_is_detected_but_unrecoverable() {
        // N=4, B=2 (g=2), verify_m=2: both replicas of batch 0 corrupt.
        // Their worker-dependent perturbations disagree with each other
        // too, so the vote detects the conflict but cannot attribute it
        // (no 2-group exists): the earliest value is accepted
        // best-effort, a degradation is counted, and nobody is flagged
        // or quarantined.
        use crate::fault::{FaultEvent, FaultPlan};
        let mut cfg = test_cfg(4, 2);
        cfg.verify_m = 2;
        let mut c = Coordinator::new(cfg, Backend::Mock).unwrap();
        let plan = FaultPlan {
            name: "cc".into(),
            seed: 3,
            events: vec![
                (0, FaultEvent::Corruption { from_round: 0, prob: 1.0 }),
                (1, FaultEvent::Corruption { from_round: 0, prob: 1.0 }),
            ],
        };
        c.install_fault_plan(&plan).unwrap();
        for round in 0..3 {
            let res = c.run_round(JobSpec::Grad { w: Arc::new(vec![0.0; 4]) }).unwrap();
            let e = res.events;
            assert_eq!(e.corrupted, 2, "round {round}");
            assert_eq!(e.degradations, 1, "round {round}: detected but unrecoverable");
            assert_eq!(e.flagged, 0, "round {round}: attribution impossible");
            assert_eq!(e.quarantined, 0, "round {round}");
            assert_eq!(c.live_workers(), 4, "round {round}");
        }
        c.shutdown();
    }
}
