//! Synthetic datasets for the live System1 (the Rust twin of
//! `python/compile/model.synth_regression`).

use crate::util::rng::Rng;

/// An in-memory regression dataset (row-major features).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Number of rows.
    pub n_samples: usize,
    /// Feature dimension.
    pub dim: usize,
    /// Row-major `n_samples×dim` features.
    pub x: Vec<f32>,
    /// Targets.
    pub y: Vec<f32>,
    /// The generating weights (ground truth for convergence checks).
    pub w_star: Vec<f32>,
}

impl Dataset {
    /// `X ~ N(0,1)`, `y = X·w* + noise·ε`, `w* ~ N(0,1)`.
    pub fn synth_regression(n_samples: usize, dim: usize, noise: f64, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let w_star: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let mut x = Vec::with_capacity(n_samples * dim);
        let mut y = Vec::with_capacity(n_samples);
        for _ in 0..n_samples {
            let row_start = x.len();
            let mut dot = 0f32;
            for j in 0..dim {
                let v = rng.normal() as f32;
                x.push(v);
                dot += v * w_star[j];
            }
            debug_assert_eq!(x.len() - row_start, dim);
            y.push(dot + noise as f32 * rng.normal() as f32);
        }
        Dataset { n_samples, dim, x, y, w_star }
    }

    /// Extract the rows covered by `ranges` (half-open, coalesced) into
    /// a contiguous shard.
    pub fn shard(&self, ranges: &[(usize, usize)]) -> crate::worker::Shard {
        let rows: usize = ranges.iter().map(|(s, e)| e - s).sum();
        let mut x = Vec::with_capacity(rows * self.dim);
        let mut y = Vec::with_capacity(rows);
        for &(s, e) in ranges {
            x.extend_from_slice(&self.x[s * self.dim..e * self.dim]);
            y.extend_from_slice(&self.y[s..e]);
        }
        crate::worker::Shard { rows, dim: self.dim, x, y }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let a = Dataset::synth_regression(100, 8, 0.1, 7);
        let b = Dataset::synth_regression(100, 8, 0.1, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.x.len(), 800);
        assert_eq!(a.y.len(), 100);
        let c = Dataset::synth_regression(100, 8, 0.1, 8);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn targets_follow_w_star() {
        // With zero noise, y row-wise equals X·w*.
        let d = Dataset::synth_regression(50, 4, 0.0, 3);
        for r in 0..50 {
            let dot: f32 =
                (0..4).map(|j| d.x[r * 4 + j] * d.w_star[j]).sum();
            assert!((dot - d.y[r]).abs() < 1e-5);
        }
    }

    #[test]
    fn shard_extraction() {
        let d = Dataset::synth_regression(10, 2, 0.0, 1);
        let s = d.shard(&[(0, 2), (8, 10)]);
        assert_eq!(s.rows, 4);
        assert_eq!(&s.x[0..4], &d.x[0..4]);
        assert_eq!(&s.x[4..8], &d.x[16..20]);
        assert_eq!(s.y, vec![d.y[0], d.y[1], d.y[8], d.y[9]]);
    }
}
