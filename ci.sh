#!/usr/bin/env bash
# Tier-1 gate for the batchrep crate (documented in ROADMAP.md).
#
#   ./ci.sh            # fmt check, release build, tests, bench smoke
#
# The bench smoke run uses BATCHREP_BENCH_FAST=1 so it finishes in
# seconds; it exists to catch bench-target bit-rot, not to measure.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== bench smoke (bench_fig2, fast mode) =="
BATCHREP_BENCH_FAST=1 cargo bench --bench bench_fig2

echo "ci.sh: all gates passed"
