#!/usr/bin/env bash
# Tier-1 gate for the batchrep crate (documented in ROADMAP.md).
#
#   ./ci.sh            # fmt check, clippy, release build, tests, bench smokes
#
# The bench smoke runs use BATCHREP_BENCH_FAST=1 so they finish in
# seconds; they exist to catch bench-target bit-rot, not to measure.
# The bench-mc smoke additionally validates the BENCH_mc.json artifact
# it writes at the repo root (the subcommand re-reads the file and
# fails on a malformed schema).
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (all targets, deny warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
else
  echo "clippy component unavailable in this toolchain; skipping lint gate"
fi

echo "== batchrep lint (determinism-invariant static analysis) =="
# The in-crate source analyzer (rules D1–D6, README "Static analysis"):
# total-order float comparisons, no wall-clock or entropy outside the
# sanctioned modules, no unwrap/expect in library code, schema-registry
# and counter/event-kind coverage. Exits nonzero on any finding not
# absorbed by rust/lint/baseline.json or a reasoned inline
# `// lint:allow(RULE): ...`; the JSON artifact is schema-validated by
# the subcommand itself before it is written.
cargo run --release -- lint --json target/LINT.json

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --features pjrt (vendored xla stub) =="
# The pjrt feature must always *compile* — offline it resolves to the
# vendored no-op xla stub (rust/vendor/xla-stub), which errors at
# runtime instead of faking results. This catches drift between
# runtime::Engine and the xla API surface it targets.
cargo build --release --features pjrt

echo "== cargo test -q =="
cargo test -q

echo "== conformance matrix (fast mode) =="
# Sweeps generated scenarios through every applicable backend pair
# (analytic/MC/DES/reference/live) with stderr-scaled z-bound
# tolerances. Fails on any disagreement; the failure output includes
# the shrunk minimal case and its BATCHREP_PROP_SEED replay seed.
cargo run --release -- conformance --fast

echo "== chaos smoke (fault-plan replay + recovery metrics) =="
# Replays the smoke fault plan (transient crash + respawn, scheduled
# slowdown, task drops) through the fault-aware event engine at --fast
# budgets and schema-validates the CHAOS artifact it writes (the
# subcommand re-reads the file and fails on a malformed schema). Same
# no-clobber rule as the bench JSONs: a full-budget artifact at the
# repo root is never overwritten by smoke numbers.
if [ -f ../CHAOS_smoke.json ]; then
  cargo run --release -- chaos smoke --fast --quiet --out target/CHAOS_smoke.json
else
  cargo run --release -- chaos smoke --fast --quiet --out ../CHAOS_smoke.json
fi

echo "== integrity smoke (m-of-g voting vs silent corruption) =="
# Sweeps vote size m x corruption probability through the verified
# event engine at --fast budgets: the certainly-corrupt column must
# reach detection rate 1.0 with zero false-positive flags, and the
# INTEGRITY artifact must schema-validate (the subcommand re-reads the
# file and fails on a malformed schema). Same no-clobber rule as the
# bench JSONs: a full-budget artifact at the repo root is never
# overwritten by smoke numbers.
if [ -f ../INTEGRITY_smoke.json ]; then
  cargo run --release -- integrity smoke --fast --quiet --out target/INTEGRITY_smoke.json
else
  cargo run --release -- integrity smoke --fast --quiet --out ../INTEGRITY_smoke.json
fi

echo "== study smoke (declarative sweep planner) =="
# Compiles the smoke preset into a deduplicated plan, runs it on the
# shared pool at --fast budgets, and schema-validates the STUDY artifact
# it writes (the subcommand re-reads the file and fails on a malformed
# schema). Same no-clobber rule as the bench JSONs: a full-budget
# artifact at the repo root is never overwritten by smoke numbers.
if [ -f ../STUDY_smoke.json ]; then
  cargo run --release -- study smoke --fast --quiet --out target/STUDY_smoke.json
else
  cargo run --release -- study smoke --fast --quiet --out ../STUDY_smoke.json
fi

echo "== obs smoke (event log capture + summarize) =="
# Runs the same smoke study with the observability sink installed
# (--events), then pushes the captured JSON-lines log through
# `obs summarize` — which schema-validates every line and fails on a
# malformed or empty log. Same no-clobber rule as the bench JSONs: a
# full-budget event log at the repo root is never overwritten.
if [ -f ../EVENTS_smoke.jsonl ]; then
  EVENTS_OUT=target/EVENTS_smoke.jsonl
else
  EVENTS_OUT=../EVENTS_smoke.jsonl
fi
cargo run --release -- study smoke --fast --quiet \
  --events "$EVENTS_OUT" --out target/STUDY_obs_smoke.json
cargo run --release -- obs summarize "$EVENTS_OUT"

echo "== control smoke (adaptive redundancy controller) =="
# Runs the closed-loop controller preset at --fast budgets and
# schema-validates the CONTROL artifact it writes (the subcommand
# re-reads the file and fails on a malformed schema). Same no-clobber
# rule as the bench JSONs.
if [ -f ../CONTROL_smoke.json ]; then
  cargo run --release -- control smoke --fast --quiet --out target/CONTROL_smoke.json
else
  cargo run --release -- control smoke --fast --quiet --out ../CONTROL_smoke.json
fi

echo "== bench smoke (bench_fig2, fast mode) =="
BATCHREP_BENCH_FAST=1 cargo bench --bench bench_fig2

echo "== bench-mc smoke (trials/sec harness) =="
if [ -f ../BENCH_mc.json ]; then
  # A measured baseline exists — don't clobber it with fast-mode
  # (smoke-quality) numbers; validate the harness against a scratch file.
  BATCHREP_BENCH_FAST=1 cargo run --release -- bench-mc --out target/BENCH_mc_smoke.json
else
  BATCHREP_BENCH_FAST=1 cargo run --release -- bench-mc --out ../BENCH_mc.json
fi

echo "== bench-des smoke (event-engine trials/sec harness) =="
if [ -f ../BENCH_des.json ]; then
  # Same no-clobber rule as bench-mc: keep the measured baseline,
  # schema-validate the harness against a scratch file.
  BATCHREP_BENCH_FAST=1 cargo run --release -- bench-des --out target/BENCH_des_smoke.json
else
  BATCHREP_BENCH_FAST=1 cargo run --release -- bench-des --out ../BENCH_des.json
fi

echo "== bench trajectory artifacts present at repo root =="
# PERF.md records a perf trajectory for the MC and DES hot loops; the
# bench smokes above seed these files on first run. If either is
# missing the trajectory is silently empty — fail loudly instead.
for f in ../BENCH_mc.json ../BENCH_des.json; do
  if [ ! -f "$f" ]; then
    name=$(basename "$f" .json)
    sub=${name#BENCH_}
    echo "error: $(basename "$f") missing at the repo root — the perf" >&2
    echo "trajectory in PERF.md has no baseline. Regenerate with:" >&2
    echo "  (cd rust && cargo run --release -- bench-${sub} --out $f)" >&2
    exit 1
  fi
done

echo "ci.sh: all gates passed"
