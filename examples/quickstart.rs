//! Quickstart: the paper's question in 40 lines.
//!
//! "I have N workers and a parallelizable job whose per-sample service
//! time is Shifted-Exponential. Into how many batches B should I split
//! the data, replicating each batch on N/B workers?"
//!
//!     cargo run --release --example quickstart

use batchrep::analysis;
use batchrep::des::{montecarlo, Scenario};
use batchrep::dist::{BatchService, ServiceSpec};

fn main() -> anyhow::Result<()> {
    let n = 24u64;
    let spec = ServiceSpec::shifted_exp(1.0, 0.2); // mu=1, Delta=0.2

    println!("N = {n} workers, per-sample service {}\n", spec.name());
    println!("{:>4} {:>6} {:>12} {:>12} {:>14}", "B", "g=N/B", "E[T] theory", "E[T] sim", "Var[T] theory");
    for p in analysis::spectrum(n, &spec)? {
        let scn = Scenario::paper_balanced(
            n as usize,
            p.b as usize,
            BatchService::paper(spec.clone()),
        )?;
        let mc = montecarlo::run_trials(&scn, 50_000, 42);
        println!(
            "{:>4} {:>6} {:>12.4} {:>12.4} {:>14.4}",
            p.b, p.g, p.stats.mean, mc.mean(), p.stats.var
        );
    }

    let b_star = analysis::optimum_b(n, &spec);
    let b_var = analysis::optimum_b_variance(n, &spec);
    println!("\nmean-optimal  B* = {b_star}  (Theorem 3)");
    println!("variance-optimal B = {b_var}  (Theorem 4)");
    if b_star != b_var {
        println!("=> the paper's mean-variance trade-off: you cannot have both.");
    }
    Ok(())
}
