//! Quickstart: the paper's question through the unified Evaluator API.
//!
//! "I have N workers and a parallelizable job whose per-sample service
//! time is Shifted-Exponential. Into how many batches B should I split
//! the data, replicating each batch on N/B workers?"
//!
//! One self-describing `Scenario` per point on the spectrum; the exact
//! closed form and the Monte-Carlo simulator are just two backends
//! consuming it — swapping them is a one-line change, and
//! `cross_check` validates them against each other (the paper's own
//! Fig. 2 theory-vs-simulation check).
//!
//!     cargo run --release --example quickstart

use batchrep::analysis;
use batchrep::des::Scenario;
use batchrep::dist::{BatchService, ServiceSpec};
use batchrep::evaluator::{
    cross_check, AnalyticEvaluator, Evaluator, MonteCarloEvaluator, ReplicationPolicy,
};

fn main() -> anyhow::Result<()> {
    let n = 24usize;
    let spec = ServiceSpec::shifted_exp(1.0, 0.2); // mu=1, Delta=0.2
    let mc = MonteCarloEvaluator { trials: 50_000, threads: 1 };

    println!("N = {n} workers, per-sample service {}\n", spec.name());
    println!(
        "{:>4} {:>6} {:>12} {:>12} {:>12} {:>14}",
        "B", "g=N/B", "E[T] theory", "E[T] sim", "p99 theory", "E[cost] theory"
    );
    for b in batchrep::assignment::feasible_batch_counts(n) {
        let scn = Scenario::from_policy(
            ReplicationPolicy::BalancedDisjoint,
            n,
            b,
            BatchService::paper(spec.clone()),
            42 + b as u64,
        )?;
        // Same scenario, two backends — validated against each other.
        let ck = cross_check(&AnalyticEvaluator, &mc, &scn)?;
        let exact = &ck.a;
        let sim = &ck.b;
        println!(
            "{:>4} {:>6} {:>12.4} {:>12.4} {:>12.4} {:>14.3}",
            b,
            n / b,
            exact.mean,
            sim.mean,
            exact.quantile(0.99).unwrap(),
            exact.cost.unwrap().busy,
        );
    }

    let b_star = analysis::optimum_b(n as u64, &spec);
    let b_var = analysis::optimum_b_variance(n as u64, &spec);
    println!("\nmean-optimal  B* = {b_star}  (Theorem 3)");
    println!("variance-optimal B = {b_var}  (Theorem 4)");
    if b_star != b_var {
        println!("=> the paper's mean-variance trade-off: you cannot have both.");
    }

    // The same scenario also runs on the event engine or the live
    // system: e.g. `DesEvaluator::default().evaluate(&scn)` — see
    // `batchrep evaluate --backend all`.
    let scn = Scenario::from_policy(
        ReplicationPolicy::BalancedDisjoint,
        n,
        b_star as usize,
        BatchService::paper(spec),
        42,
    )?;
    let des = batchrep::evaluator::DesEvaluator { trials: 20_000, ..Default::default() };
    let engine = des.evaluate(&scn)?;
    println!(
        "\nevent engine at B*: E[T] = {:.4}, busy = {:.2} worker-s, wasted = {:.2} worker-s",
        engine.mean,
        engine.cost.unwrap().busy,
        engine.cost.unwrap().wasted
    );
    Ok(())
}
