//! End-to-end driver (DESIGN.md E7): distributed SGD on the live
//! System1 across the diversity-parallelism spectrum.
//!
//! A linear-regression job (the paper's gradient-optimizer workload,
//! d=64, 4096 samples) trains for 200 steps on N=8 workers. Each step
//! is one System1 job: every worker sleeps out an injected
//! SExp-distributed straggle, then executes the AOT-compiled jax/Pallas
//! gradient kernel through PJRT; the master aggregates the earliest
//! replica of every batch, cancels the rest, and applies the update.
//! We run the full B in {1,2,4,8} sweep and report the loss curve and
//! per-step completion statistics -- the live reproduction of the
//! paper's headline metric.
//!
//!     make artifacts && cargo run --release --example distributed_training

use batchrep::analysis;
use batchrep::assignment::Policy;
use batchrep::config::SystemConfig;
use batchrep::coordinator::{Backend, Coordinator};
use batchrep::dist::ServiceSpec;
use batchrep::util::table::{fmt_f, Table};

fn main() -> anyhow::Result<()> {
    let artifact_dir = batchrep::runtime::default_artifact_dir();
    let backend = if artifact_dir.join("manifest.json").exists() && cfg!(feature = "pjrt") {
        Backend::Pjrt
    } else {
        eprintln!(
            "note: artifacts or the `pjrt` feature missing, using mock backend \
             (run `make artifacts` and build with --features pjrt)"
        );
        Backend::Mock
    };

    let n = 8usize;
    let steps = 200u64;
    let service = ServiceSpec::shifted_exp(1.0, 0.2);
    let mut summary = Table::new(
        "E7 - distributed training under stragglers (N=8, SExp(1,0.2), 200 steps)",
        &["B", "E[T] theory (units)", "measured injected (units)", "mean wall/step (s)",
          "final loss", "||w-w*||", "redundant+cancelled"],
    );

    for b in [1usize, 2, 4, 8] {
        let cfg = SystemConfig {
            n_workers: n,
            n_batches: b,
            policy: Policy::BalancedDisjoint,
            service: service.clone(),
            time_scale: 0.02, // 20 ms per abstract service unit (dominates compute,
            // so injected completion is unbiased by PJRT execution time)
            n_samples: 4096,
            dim: 64,
            seed: 42,
            artifacts_dir: artifact_dir.to_string_lossy().to_string(),
            ..SystemConfig::default()
        };
        let time_scale = cfg.time_scale;
        println!("== B = {b} ==");
        let mut coord = Coordinator::new(cfg, backend)?;
        let report = coord.run_training(steps, 0.3)?;
        for (i, loss) in report.loss_curve.iter().enumerate() {
            if i % 40 == 0 || i + 1 == steps as usize {
                println!("  step {i:>4}  loss {loss:.6}");
            }
        }
        let cf = analysis::completion_time_stats(n as u64, b as u64, &service)?;
        let m = &coord.metrics;
        let (_, r, c) = m.totals();
        summary.row(vec![
            b.to_string(),
            fmt_f(cf.mean, 3),
            fmt_f(m.mean_injected() / time_scale, 3),
            fmt_f(m.mean_wall(), 4),
            format!("{:.6}", report.loss_curve.last().unwrap()),
            fmt_f(report.dist_to_w_star, 4),
            format!("{}", r + c),
        ]);
        coord.shutdown();
    }

    println!();
    summary.print();
    summary.write_to(std::path::Path::new("results"), "e2e_training")?;
    println!("written to results/e2e_training.{{csv,md}}");
    Ok(())
}
