//! Regenerate paper Fig. 2 (E[T] vs B for several Delta*mu) and, when
//! AOT artifacts are present, validate the curve on the LIVE System1
//! (real worker threads executing PJRT-compiled jax/Pallas kernels with
//! injected stragglers).
//!
//!     make artifacts && cargo run --release --example diversity_sweep

use batchrep::experiments::{fig2, live, ExpContext};

fn main() -> anyhow::Result<()> {
    let ctx = ExpContext {
        out_dir: "results".into(),
        trials: 200_000,
        seed: 42,
    };
    std::fs::create_dir_all(&ctx.out_dir)?;

    println!("== Fig. 2: analytic + simulated curves ==\n");
    fig2::run(&ctx)?;

    println!("\n== Live System1 validation (threads + PJRT) ==\n");
    live::run(&ctx)?;
    Ok(())
}
