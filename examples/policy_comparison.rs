//! Theorem 1 live: compare batch->worker assignment policies on the
//! simulator, including the overlapping layout, under distributions
//! that satisfy (and violate) the theorem's hypothesis.
//!
//!     cargo run --release --example policy_comparison

use batchrep::experiments::{policies, ExpContext};

fn main() -> anyhow::Result<()> {
    let ctx = ExpContext {
        out_dir: "results".into(),
        trials: 100_000,
        seed: 42,
    };
    std::fs::create_dir_all(&ctx.out_dir)?;
    policies::run(&ctx)?;
    println!("\n(also written to results/thm1_policies.{{csv,md}})");
    Ok(())
}
