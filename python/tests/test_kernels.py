"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is
the core correctness signal for everything the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.grad import grad_pallas
from compile.kernels.mapsum import mapsum_pallas
from compile.kernels.ref import grad_ref, mapsum_ref

SHAPES = st.tuples(
    st.integers(min_value=1, max_value=300),  # rows (crosses TILE_S=128)
    st.integers(min_value=1, max_value=40),   # dim
)


def make_data(rows, dim, dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, dim)).astype(dtype)
    y = rng.standard_normal((rows,)).astype(dtype)
    w = rng.standard_normal((dim,)).astype(dtype)
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(w)


class TestGradKernel:
    @settings(max_examples=40, deadline=None)
    @given(shape=SHAPES, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_f32(self, shape, seed):
        rows, dim = shape
        x, y, w = make_data(rows, dim, np.float32, seed)
        g_k, loss_k = grad_pallas(x, y, w)
        g_r, loss_r = grad_ref(x, y, w)
        assert_allclose(np.asarray(g_k), np.asarray(g_r), rtol=2e-4, atol=2e-4)
        assert_allclose(float(loss_k), float(loss_r), rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("rows", [1, 127, 128, 129, 256, 257])
    def test_tile_boundaries(self, rows):
        """Shapes straddling the TILE_S boundary exercise padding."""
        x, y, w = make_data(rows, 8, np.float32, rows)
        g_k, loss_k = grad_pallas(x, y, w)
        g_r, loss_r = grad_ref(x, y, w)
        assert_allclose(np.asarray(g_k), np.asarray(g_r), rtol=2e-4, atol=2e-4)
        assert_allclose(float(loss_k), float(loss_r), rtol=2e-4, atol=2e-4)

    @settings(max_examples=15, deadline=None)
    @given(shape=SHAPES, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_bf16(self, shape, seed):
        """bfloat16 inputs: kernel and oracle agree at bf16 tolerance
        (the dtype the TPU MXU natively consumes)."""
        rows, dim = shape
        x, y, w = make_data(rows, dim, np.float32, seed)
        xb, yb, wb = (v.astype(jnp.bfloat16) for v in (x, y, w))
        g_k, loss_k = grad_pallas(xb, yb, wb)
        g_r, loss_r = grad_ref(xb, yb, wb)
        assert g_k.dtype == jnp.bfloat16
        assert_allclose(
            np.asarray(g_k, np.float32),
            np.asarray(g_r, np.float32),
            rtol=0.05,
            atol=0.1 * max(1, rows) ** 0.5,
        )
        assert_allclose(
            float(loss_k), float(loss_r), rtol=0.05, atol=0.1 * max(1, rows)
        )

    def test_gradient_is_true_gradient(self):
        """Kernel output equals jax.grad of the batch loss."""
        x, y, w = make_data(96, 12, np.float32, 7)

        def loss_fn(w):
            r = x @ w - y
            return 0.5 * jnp.sum(r * r)

        g_auto = jax.grad(loss_fn)(w)
        g_k, _ = grad_pallas(x, y, w)
        assert_allclose(np.asarray(g_k), np.asarray(g_auto), rtol=2e-4, atol=2e-4)

    def test_zero_residual_zero_grad(self):
        x, _, w = make_data(64, 6, np.float32, 3)
        y = x @ w  # perfect fit
        g_k, loss_k = grad_pallas(x, y, w)
        assert_allclose(np.asarray(g_k), np.zeros(6), atol=1e-4)
        assert float(loss_k) == pytest.approx(0.0, abs=1e-6)

    def test_additivity_across_batches(self):
        """The master's aggregation invariant: grad sums over disjoint
        batches add up to the whole-dataset gradient."""
        x, y, w = make_data(200, 10, np.float32, 11)
        g_all, loss_all = grad_pallas(x, y, w)
        g_sum = jnp.zeros(10)
        loss_sum = 0.0
        for lo, hi in [(0, 50), (50, 125), (125, 200)]:
            g_b, loss_b = grad_pallas(x[lo:hi], y[lo:hi], w)
            g_sum = g_sum + g_b
            loss_sum += float(loss_b)
        assert_allclose(np.asarray(g_sum), np.asarray(g_all), rtol=1e-3, atol=1e-3)
        assert loss_sum == pytest.approx(float(loss_all), rel=1e-3)


class TestMapsumKernel:
    @settings(max_examples=40, deadline=None)
    @given(shape=SHAPES, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_f32(self, shape, seed):
        rows, dim = shape
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((rows, dim)).astype(np.float32))
        a = jnp.asarray(rng.standard_normal(dim).astype(np.float32))
        b = jnp.asarray(rng.standard_normal(dim).astype(np.float32))
        out_k = mapsum_pallas(x, a, b)
        out_r = mapsum_ref(x, a, b)
        # tanh output in (-1,1); sums scale with rows.
        assert_allclose(float(out_k), float(out_r), rtol=2e-4, atol=2e-4 * rows)

    def test_padding_exactness(self):
        """Zero rows score tanh(0)=0: padded and unpadded agree."""
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.standard_normal((130, 4)).astype(np.float32))
        a = jnp.ones(4, jnp.float32)
        b = jnp.zeros(4, jnp.float32)
        assert_allclose(
            float(mapsum_pallas(x, a, b)), float(mapsum_ref(x, a, b)), rtol=1e-4
        )

    def test_additivity_across_batches(self):
        rng = np.random.default_rng(9)
        x = jnp.asarray(rng.standard_normal((300, 6)).astype(np.float32))
        a = jnp.asarray(rng.standard_normal(6).astype(np.float32))
        b = jnp.asarray(rng.standard_normal(6).astype(np.float32))
        whole = float(mapsum_pallas(x, a, b))
        parts = sum(
            float(mapsum_pallas(x[lo:hi], a, b)) for lo, hi in [(0, 100), (100, 300)]
        )
        assert parts == pytest.approx(whole, rel=1e-3, abs=1e-3)

    def test_bounded_scores(self):
        """|f(x_i)| < 1 ⇒ |sum| < rows."""
        rng = np.random.default_rng(13)
        x = jnp.asarray(100.0 * rng.standard_normal((50, 3)).astype(np.float32))
        a = jnp.ones(3, jnp.float32)
        b = jnp.ones(3, jnp.float32)
        assert abs(float(mapsum_pallas(x, a, b))) <= 50.0
