"""L2 correctness: model-level jobs, aggregation semantics, SGD step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model


def test_batch_grad_matches_autodiff():
    key = jax.random.PRNGKey(0)
    x, y, _ = model.synth_regression(key, 128, 16)
    w = jax.random.normal(jax.random.PRNGKey(1), (16,))
    g, loss = model.batch_grad(x, y, w)
    g_auto = model.full_grad(x, y, w)
    assert_allclose(np.asarray(g), np.asarray(g_auto), rtol=2e-4, atol=2e-4)
    assert float(loss) == pytest.approx(float(model.full_loss(x, y, w)), rel=2e-4)


def test_sharded_aggregation_equals_global_gradient():
    """System1's result-generation identity: summing per-batch gradient
    sums over a disjoint partition reproduces the global gradient."""
    key = jax.random.PRNGKey(2)
    x, y, _ = model.synth_regression(key, 256, 8)
    w = jax.random.normal(jax.random.PRNGKey(3), (8,))
    shards = [(0, 64), (64, 128), (128, 256)]
    g_total = jnp.zeros(8)
    for lo, hi in shards:
        g_b, _ = model.batch_grad(x[lo:hi], y[lo:hi], w)
        g_total = g_total + g_b
    assert_allclose(
        np.asarray(g_total), np.asarray(model.full_grad(x, y, w)), rtol=1e-3, atol=1e-3
    )


def test_sgd_converges_on_synthetic_data():
    """A few hundred full-batch SGD steps recover w* — the semantic the
    distributed e2e example must reproduce through the Rust stack."""
    key = jax.random.PRNGKey(4)
    n, d = 512, 8
    x, y, w_star = model.synth_regression(key, n, d, noise=0.01)
    w = jnp.zeros(d)
    for _ in range(200):
        g, _ = model.batch_grad(x, y, w)
        w = model.sgd_step(w, g, n, lr=0.5)
    assert float(jnp.linalg.norm(w - w_star)) < 0.1


def test_mapsum_job_tuple_shape():
    x = jnp.ones((16, 4))
    a = jnp.ones(4)
    b = jnp.zeros(4)
    out = model.batch_mapsum(x, a, b)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == ()
