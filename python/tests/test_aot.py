"""AOT path: lowering produces loadable HLO text and a valid manifest."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out), rows_list=[8], dims_list=[4])
    return out, manifest


def test_manifest_schema(built):
    out, manifest = built
    assert manifest["version"] == 1
    assert len(manifest["artifacts"]) == 2  # grad + mapsum at (8,4)
    for a in manifest["artifacts"]:
        assert a["kernel"] in ("grad", "mapsum")
        assert os.path.exists(out / a["file"])
        assert a["outputs"] in (1, 2)
        assert all(len(spec) == 2 for spec in a["inputs"])


def test_hlo_text_is_parseable_entry(built):
    out, manifest = built
    for a in manifest["artifacts"]:
        text = (out / a["file"]).read_text()
        # HLO text essentials the Rust-side parser requires.
        assert "HloModule" in text
        assert "ENTRY" in text
        assert "f32[" in text


def test_manifest_json_round_trips(built):
    out, _ = built
    with open(out / "manifest.json") as f:
        m = json.load(f)
    assert {a["kernel"] for a in m["artifacts"]} == {"grad", "mapsum"}


def test_grad_hlo_declares_expected_shapes(built):
    out, manifest = built
    grad = next(a for a in manifest["artifacts"] if a["kernel"] == "grad")
    text = (out / grad["file"]).read_text()
    assert "f32[8,4]" in text  # X input
    assert "f32[4]" in text    # w input / g output
