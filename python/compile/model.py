"""L2: the JAX compute jobs System1 distributes (build-time only).

The paper's System1 runs an arbitrary "executable" over data batches;
its motivating workloads are gradient-based optimizers and map-sum
evaluations (§II). This module defines those jobs as jax functions that
call the L1 Pallas kernels, in the exact calling convention the Rust
runtime uses after AOT lowering:

* ``batch_grad(x, y, w) -> (g, loss)`` — per-batch least-squares
  gradient + loss *sums*, aggregated exactly by the master across
  batches (g_total = Σ g_b over the earliest replica of every batch).
* ``batch_mapsum(x, a, b) -> (total,)`` — per-batch map-sum.

Python never runs at request time: ``aot.py`` lowers these functions to
HLO text once per (rows, dim) variant; the Rust coordinator loads and
executes the artifacts through PJRT.
"""

import jax
import jax.numpy as jnp

from compile.kernels.grad import grad_pallas
from compile.kernels.mapsum import mapsum_pallas


def batch_grad(x, y, w):
    """Per-batch gradient job. Returns a tuple (lowered with
    return_tuple=True; the Rust side unwraps a 2-tuple)."""
    g, loss = grad_pallas(x, y, w)
    return (g, loss)


def batch_mapsum(x, a, b):
    """Per-batch map-sum job. Returns a 1-tuple."""
    return (mapsum_pallas(x, a, b),)


def full_loss(x, y, w):
    """Whole-dataset mean-squared-error loss (0.5·mean r²) — used by the
    tests to check that aggregated per-batch gradients equal the true
    gradient of the global objective."""
    r = x @ w - y
    return 0.5 * jnp.sum(r * r)


def full_grad(x, y, w):
    """jax.grad oracle for the aggregated gradient."""
    return jax.grad(full_loss, argnums=2)(x, y, w)


def sgd_step(w, g_total, n_samples, lr):
    """The master's result-generation step: one SGD update from the
    aggregated gradient *sum* (normalized to a mean). Pure jnp; the Rust
    coordinator re-implements this trivially in f32 — kept here as the
    semantic reference."""
    return w - lr * g_total / n_samples


def synth_regression(key, n_samples, dim, noise=0.1):
    """Synthetic linear-regression dataset: X ~ N(0,1), y = X·w* + ε.
    The e2e example trains against this and must recover w*."""
    k1, k2, k3 = jax.random.split(key, 3)
    w_star = jax.random.normal(k1, (dim,))
    x = jax.random.normal(k2, (n_samples, dim))
    y = x @ w_star + noise * jax.random.normal(k3, (n_samples,))
    return x, y, w_star
