"""AOT lowering: jax/Pallas jobs → HLO text artifacts + manifest.

Run once by ``make artifacts``::

    python python/compile/aot.py --out artifacts

For every (kernel, rows, dim) variant the Rust coordinator may dispatch,
this lowers the jitted L2 function to **HLO text** and records it in
``manifest.json``. Text — not ``.serialize()`` — is the interchange
format: jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the HLO text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Manifest schema (consumed by rust/src/runtime):

    {"version": 1,
     "artifacts": [{"kernel": "grad", "rows": 512, "dim": 64,
                    "file": "grad_r512_d64.hlo.txt",
                    "inputs": [["512,64","f32"], ...],
                    "outputs": 2}, ...]}
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from compile import model

# The live coordinator shards n_samples rows over B batches; with the
# default e2e config (n_samples=4096, N=8, B ∈ {1,2,4,8}) plus the small
# validation variants used by tests and the quickstart.
DEFAULT_ROWS = [8, 64, 512, 1024, 2048, 4096]
DEFAULT_DIMS = [4, 64]


def to_hlo_text(lowered) -> str:
    """Lowered jax function → HLO text (the 0.5.1-safe interchange).

    ``compiler_ir(dialect="hlo")`` converts inside jax's own bundled XLA
    (which understands current StableHLO, including the dynamic-slice
    forms Pallas grids emit) and prints classic HLO text, which the
    old xla_extension's text parser accepts and re-ids. The stablehlo →
    ``mlir_module_to_xla_computation`` route in the reference recipe
    fails here: the 0.5.1-era converter cannot parse jax 0.8's
    StableHLO (`custom op 'stablehlo.dynamic_slice' expected 'sizes'`).

    The L2 jobs return tuples, so the entry root is already a tuple —
    no ``return_tuple`` knob is needed.
    """
    return lowered.compiler_ir(dialect="hlo").as_hlo_text()


def lower_grad(rows: int, dim: int) -> str:
    x = jax.ShapeDtypeStruct((rows, dim), jnp.float32)
    y = jax.ShapeDtypeStruct((rows,), jnp.float32)
    w = jax.ShapeDtypeStruct((dim,), jnp.float32)
    return to_hlo_text(jax.jit(model.batch_grad).lower(x, y, w))


def lower_mapsum(rows: int, dim: int) -> str:
    x = jax.ShapeDtypeStruct((rows, dim), jnp.float32)
    a = jax.ShapeDtypeStruct((dim,), jnp.float32)
    b = jax.ShapeDtypeStruct((dim,), jnp.float32)
    return to_hlo_text(jax.jit(model.batch_mapsum).lower(x, a, b))


def build(out_dir: str, rows_list, dims_list) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    artifacts = []
    for dim in dims_list:
        for rows in rows_list:
            for kernel, lower, inputs, outputs in (
                (
                    "grad",
                    lower_grad,
                    [[f"{rows},{dim}", "f32"], [f"{rows}", "f32"], [f"{dim}", "f32"]],
                    2,
                ),
                (
                    "mapsum",
                    lower_mapsum,
                    [[f"{rows},{dim}", "f32"], [f"{dim}", "f32"], [f"{dim}", "f32"]],
                    1,
                ),
            ):
                fname = f"{kernel}_r{rows}_d{dim}.hlo.txt"
                text = lower(rows, dim)
                with open(os.path.join(out_dir, fname), "w") as f:
                    f.write(text)
                artifacts.append(
                    {
                        "kernel": kernel,
                        "rows": rows,
                        "dim": dim,
                        "file": fname,
                        "inputs": inputs,
                        "outputs": outputs,
                    }
                )
                print(f"  lowered {fname} ({len(text)} chars)")
    manifest = {"version": 1, "artifacts": artifacts}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(artifacts)} artifacts to {out_dir}/")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="artifacts")
    ap.add_argument("--rows", type=int, nargs="*", default=DEFAULT_ROWS)
    ap.add_argument("--dims", type=int, nargs="*", default=DEFAULT_DIMS)
    args = ap.parse_args()
    build(args.out, args.rows, args.dims)


if __name__ == "__main__":
    main()
