"""L1 Pallas kernel: fused map-sum f(D) = Σ_i f(X_i).

The paper's §II example computation: evaluate a per-sample function and
sum the results. f(x_i) = tanh(Σ_j a_j·x_ij² + b_j·x_ij) fuses an
elementwise polynomial (VPU work), a feature-axis reduction, a tanh, and
a sample-axis reduction into a single pass over each (TILE_S, d) VMEM
tile, accumulating into a scalar output block that stays resident across
the grid. Zero-row padding is *not* exact for this f (tanh(0) = 0, so it
is — each padded row scores tanh(0)=0), see the masking note below.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Same tile policy as grad.py (§Perf iteration 3).
TILE_S = 512


def _mapsum_kernel(x_ref, a_ref, b_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                                    # (tile, d)
    per_feature = a_ref[...][None, :] * x * x + b_ref[...][None, :] * x
    scores = jnp.tanh(jnp.sum(per_feature, axis=1))   # (tile,)
    o_ref[...] += jnp.sum(scores)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mapsum_pallas(x, a, b, interpret=True):
    """Pallas map-sum. Returns a scalar like ref.mapsum_ref.

    Padding note: a zero row contributes tanh(0) = 0 to the sum, so
    zero-padding the sample axis is exact for this f. (A general f would
    need an explicit row mask; keep that in mind when swapping f.)
    """
    s, d = x.shape
    tile = min(TILE_S, max(s, 1))
    pad = (-s) % tile
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)], axis=0)
    n_tiles = x.shape[0] // tile

    out = pl.pallas_call(
        _mapsum_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((), lambda i: ()),
        out_shape=jax.ShapeDtypeStruct((), x.dtype),
        interpret=interpret,
    )(x, a, b)
    return out
