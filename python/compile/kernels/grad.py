"""L1 Pallas kernel: tiled least-squares partial gradient.

Computes, over one data batch held by a worker,

    g    = Xᵀ(X·w − y)        (gradient sum)
    loss = ½‖X·w − y‖²        (loss sum)

tiled along the sample axis so each (TILE_S, d) block of X streams
through VMEM once and feeds two MXU-shaped contractions per tile:
`(TILE_S×d)·(d)` for the residual and `(d×TILE_S)·(TILE_S)` for the
gradient accumulation. The output block index map is constant, so the
(d,)-gradient and scalar loss stay VMEM-resident as accumulators across
the whole grid (the revisited-output-block idiom).

TPU mapping (DESIGN.md §Hardware-Adaptation): with d = 256 and
TILE_S = 128 an f32 X-tile is 128 KiB — double-buffered comfortably
inside ~16 MiB VMEM; the contraction shapes are MXU-systolic-friendly.
`interpret=True` is mandatory here: the CPU PJRT client cannot execute
Mosaic custom-calls, and the interpret lowering emits plain HLO that the
Rust runtime loads byte-for-byte.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sample-axis tile (a multiple of the MXU's 128-lane systolic
# dimension). A (1024, 256) f32 block is 1 MiB — double-buffered it
# sits comfortably inside ~16 MiB VMEM — and larger tiles shrink the
# grid-loop trip count, which is what the interpret-mode CPU execution
# pays for. §Perf iterations: 128 → 512 cut the rows=4096 artifact's
# latency 2.7× (4.66 → 1.75 ms), 512 → 1024 another 7% (1.63 ms);
# 2048 was <5% and is past the d=256 double-buffer budget, so 1024 is
# the stopping point. Numerics identical at every tile (pytest).
TILE_S = 1024


def _grad_kernel(x_ref, y_ref, w_ref, g_ref, loss_ref):
    """One grid step: fold one sample tile into the accumulators."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    x = x_ref[...]                        # (tile, d)
    r = x @ w_ref[...] - y_ref[...]       # (tile,)
    g_ref[...] += r @ x                   # (d,)  == Xᵀr for this tile
    loss_ref[...] += 0.5 * jnp.sum(r * r)


@functools.partial(jax.jit, static_argnames=("interpret",))
def grad_pallas(x, y, w, interpret=True):
    """Pallas partial gradient. Returns (g, loss) like ref.grad_ref.

    Pads the sample axis up to a TILE_S multiple with zero rows (zero
    rows contribute zero residual and zero gradient, so padding is
    exact; y is padded with zeros to match).
    """
    s, d = x.shape
    tile = min(TILE_S, max(s, 1))
    pad = (-s) % tile
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)], axis=0)
        y = jnp.concatenate([y, jnp.zeros((pad,), y.dtype)], axis=0)
    n_tiles = x.shape[0] // tile

    g, loss = pl.pallas_call(
        _grad_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),   # stream X tiles
            pl.BlockSpec((tile,), lambda i: (i,)),       # stream y tiles
            pl.BlockSpec((d,), lambda i: (0,)),          # w resident
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),          # g accumulator
            pl.BlockSpec((), lambda i: ()),              # loss accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), x.dtype),
            jax.ShapeDtypeStruct((), x.dtype),
        ],
        interpret=interpret,
    )(x, y, w)
    return g, loss
